"""Unified model configuration covering the 10 assigned architectures plus
the paper's own XMC encoders.

A model is a repeating *pattern* of blocks (period P); layers = n_periods × P.
Uniform architectures have P=1; llama-3.2-vision has P=5 (4 self-attn + 1
cross-attn layer); xlstm has P=6 (5 mLSTM + 1 sLSTM).  Parameters are stacked
over periods and the decoder scans over them (HLO size O(P), not O(L) — see
DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One block position inside the repeating pattern."""
    kind: str = "attn"            # attn | mamba | hymba | mlstm | slstm
    cross_attn: bool = False      # add gated cross-attention (VLM)
    moe: bool = False             # FFN is a mixture of experts
    ffn: str = "swiglu"           # swiglu | geglu | gelu | none


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    pattern: Tuple[BlockSpec, ...] = (BlockSpec(),)
    # attention
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None    # SWA width (mixtral/hymba)
    qk_norm: bool = False                   # qwen3
    attn_logit_softcap: Optional[float] = None
    # MoE
    n_experts: int = 0
    top_k: int = 2
    moe_dense_residual: bool = False        # arctic: dense FFN ∥ MoE
    capacity_factor: float = 1.25
    # dispatch mode: "auto" = EP-over-model when divisible else TP-in-expert;
    # "a2a" = tokens all_to_all'd to resident 2-D-sharded experts
    # (E over data × F over model) — weights never move (§Perf A2)
    moe_mode: str = "auto"
    # SSM / recurrent
    ssm_state: int = 16
    ssm_expand: int = 2
    mlstm_heads: int = 4
    # frontends (stub: precomputed embeddings are model inputs)
    frontend: Optional[str] = None          # None | "audio_frames" | "vision"
    n_frontend_tokens: int = 0              # e.g. image patches per sample
    # ELMO head
    head_chunks: int = 8
    head_weight_dtype: str = "e4m3"
    head_kahan_chunks: int = 0
    head_labels: Optional[int] = None   # XMC: label count (BCE head);
    #                                     None → LM head over vocab (CE)
    # fixed-fan-in sparse head (DESIGN.md §13): 0 = dense; > 0 keeps that
    # many weight slots per label row (values + i32 indices)
    head_fan_in: int = 0
    head_prune_every: int = 0           # prune/regrow cadence in steps (0=off)
    # numerics guard (DESIGN.md §14): emit per-step saturation/non-finite
    # telemetry from the head train step (bitwise invisible to the weights)
    head_guard: bool = False
    # encoder-style (paper's own XMC archs)
    causal: bool = True
    pool: str = "none"                  # "none" (LM) | "first" (CLS pooling)
    max_labels_per_example: int = 40    # P in the sparse multi-label targets
    # numerics
    param_dtype: str = "bf16"
    norm_eps: float = 1e-6
    # gradient accumulation: microbatches per step (divides token-
    # proportional transients — MoE dispatch buffers, head chunk logits,
    # activations — at the cost of re-running the backbone per microbatch)
    grad_accum: int = 1
    # sharding strategy (§Perf hillclimb lever):
    #   "tp_sp"     — TP over model axis + sequence parallelism (baseline)
    #   "fsdp_pure" — batch sharded over (data × model), params FSDP over
    #                 both; no per-layer activation collectives. Right for
    #                 dense models whose params ≪ activations (roofline).
    sharding_strategy: str = "tp_sp"
    # long-context support marker (DESIGN.md §3 skip rule)
    subquadratic: bool = False

    @property
    def hdim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, \
            f"{self.name}: {self.n_layers} layers not divisible by period " \
            f"{self.period}"
        return self.n_layers // self.period

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def head_size(self) -> int:
        """Output-space size the ELMO head covers (labels or vocab)."""
        return self.head_labels if self.head_labels else self.vocab

    @property
    def head_loss(self) -> str:
        return "bce" if self.head_labels else "softmax_ce"

    def validate(self) -> None:
        assert self.n_heads % self.n_kv_heads == 0
        if any(b.moe for b in self.pattern):
            assert self.n_experts > 0
        _ = self.n_periods


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test scale: same family/pattern, tiny dims (spec: REDUCED config
    of the same family)."""
    small = dict(
        n_layers=2 * cfg.period,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, 4 // max(1, cfg.n_heads // cfg.n_kv_heads)),
        d_ff=128 if cfg.d_ff else 0,
        vocab=503,
        head_dim=16 if cfg.head_dim else None,
        n_experts=4 if cfg.n_experts else 0,
        sliding_window=8 if cfg.sliding_window else None,
        ssm_state=4,
        mlstm_heads=2,
        n_frontend_tokens=3 if cfg.n_frontend_tokens else 0,
        head_chunks=4,
        grad_accum=1,
        name=cfg.name + "-smoke",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
