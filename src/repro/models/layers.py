"""Primitive layers: norms, RoPE, initializers. Pure functions over pytrees."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

BF16 = jnp.bfloat16


def dense_init(key, d_in: int, d_out: int, dtype=BF16, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def dense(w, x):
    """x @ w with f32 accumulation, result in x.dtype."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def rmsnorm_init(d: int, dtype=BF16):
    return jnp.ones((d,), dtype)


def rmsnorm(g, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * g.astype(jnp.float32)
            ).astype(x.dtype)


def layernorm_init(d: int, dtype=BF16):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32) + p["b"].astype(jnp.float32)
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (Dh/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., :, None, :]                  # (..., S, 1, Dh/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : dh // 2], x32[..., dh // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype=BF16):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


def embed_lookup(table, ids):
    return jnp.take(table, ids, axis=0)
