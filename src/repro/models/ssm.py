"""Mamba selective SSM (for hymba's parallel SSM heads).

Training uses a *chunked associative scan*: the sequence is split into
chunks; within a chunk the linear recurrence h_t = a_t·h_{t-1} + b_t is
solved with ``jax.lax.associative_scan`` (log-depth, parallel), and the chunk
boundary state is carried sequentially.  Transient memory is O(chunk), which
is what lets the 500k-token cells compile (DESIGN.md §3).

Decode carries (h, conv window) — O(1) per token regardless of context
length: the reason SSM/hybrid archs run the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as Ly
from repro.models.config import ModelConfig

CONV_K = 4


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


def ssm_init(key, cfg: ModelConfig) -> dict:
    D, DI, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    R = _dt_rank(cfg)
    ks = jax.random.split(key, 7)
    return {
        "in_proj": Ly.dense_init(ks[0], D, 2 * DI),
        "conv_w": (jax.random.normal(ks[1], (CONV_K, DI), jnp.float32)
                   * (1.0 / np.sqrt(CONV_K))).astype(Ly.BF16),
        "conv_b": jnp.zeros((DI,), Ly.BF16),
        "x_proj": Ly.dense_init(ks[2], DI, R + 2 * N),
        "dt_proj": Ly.dense_init(ks[3], R, DI, scale=1.0 / np.sqrt(R)),
        "dt_bias": jnp.full((DI,), -2.0, jnp.float32),  # softplus ≈ 0.12
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (DI, N))),
        "D_skip": jnp.ones((DI,), jnp.float32),
        "out_proj": Ly.dense_init(ks[4], DI, D, scale=1.0 / np.sqrt(DI)),
    }


def _causal_conv(w, b, x, state=None):
    """Depthwise causal conv, kernel CONV_K. x: (B, S, DI)."""
    if state is None:
        xp = jnp.pad(x, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state, x], axis=1)     # (B, K-1+S, DI)
    S = x.shape[1]
    out = sum(xp[:, i:i + S] * w[i][None, None, :] for i in range(CONV_K))
    new_state = xp[:, -(CONV_K - 1):]
    return out + b[None, None, :], new_state


def _ssm_inner(p, cfg: ModelConfig, x_c, h0, chunk: int):
    """Selective scan over (B, S, DI) with initial state h0 (B, DI, N)."""
    B, S, DI = x_c.shape
    N = cfg.ssm_state
    R = _dt_rank(cfg)
    proj = jnp.dot(x_c, p["x_proj"], preferred_element_type=jnp.float32)
    dt_in, Bm, Cm = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(jnp.dot(dt_in, p["dt_proj"],
                                 preferred_element_type=jnp.float32)
                         + p["dt_bias"])                       # (B,S,DI)
    A = -jnp.exp(p["A_log"])                                   # (DI, N)

    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x_c2 = jnp.pad(x_c, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    else:
        x_c2 = x_c
    nc = (S + pad) // chunk

    def chunk_body(h, inp):
        xc_w, dt_w, b_w, c_w = inp                             # (B,W,·)
        decay = jnp.exp(dt_w[..., None] * A)                   # (B,W,DI,N)
        inc = (dt_w * xc_w.astype(jnp.float32))[..., None] * b_w[:, :, None, :]

        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        a_pref, b_pref = jax.lax.associative_scan(comb, (decay, inc), axis=1)
        hs = a_pref * h[:, None] + b_pref                      # (B,W,DI,N)
        y = (hs * c_w[:, :, None, :]).sum(-1)                  # (B,W,DI)
        return hs[:, -1], y

    xs = (x_c2.reshape(B, nc, chunk, DI).swapaxes(0, 1),
          dt.reshape(B, nc, chunk, DI).swapaxes(0, 1),
          Bm.reshape(B, nc, chunk, N).swapaxes(0, 1),
          Cm.reshape(B, nc, chunk, N).swapaxes(0, 1))
    # remat: without it, autodiff saves the (B,W,DI,N) decay/prefix tensors
    # of EVERY chunk — the full-sequence state blow-up chunking exists to
    # avoid.  Rematerializing keeps only the (B,DI,N) boundary carries.
    h_last, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, nc * chunk, DI)[:, :S]
    y = y + p["D_skip"] * x_c.astype(jnp.float32)
    return y, h_last


def ssm_apply(p, cfg: ModelConfig, x, chunk: int = 128) -> jax.Array:
    """Training forward. x: (B, S, D) → (B, S, D)."""
    B, S, D = x.shape
    xz = Ly.dense(p["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv, _ = _causal_conv(p["conv_w"], p["conv_b"], x_in)
    x_c = jax.nn.silu(x_conv.astype(jnp.float32)).astype(x.dtype)
    h0 = jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32)
    y, _ = _ssm_inner(p, cfg, x_c, h0, chunk)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return Ly.dense(p["out_proj"], y)


class SSMCache(NamedTuple):
    h: jax.Array           # (B, DI, N)
    conv: jax.Array        # (B, CONV_K-1, DI)


def init_ssm_cache(cfg: ModelConfig, batch: int) -> SSMCache:
    return SSMCache(jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
                    jnp.zeros((batch, CONV_K - 1, cfg.d_inner), Ly.BF16))


def ssm_decode(p, cfg: ModelConfig, x, cache: SSMCache
               ) -> Tuple[jax.Array, SSMCache]:
    """One-token step. x: (B, 1, D)."""
    xz = Ly.dense(p["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv, conv_state = _causal_conv(p["conv_w"], p["conv_b"],
                                      x_in, cache.conv.astype(x_in.dtype))
    x_c = jax.nn.silu(x_conv.astype(jnp.float32)).astype(x.dtype)
    y, h = _ssm_inner(p, cfg, x_c, cache.h, chunk=1)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return Ly.dense(p["out_proj"], y), SSMCache(h, conv_state.astype(Ly.BF16))
