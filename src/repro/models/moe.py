"""Mixture-of-Experts with expert parallelism.

Routing is top-k softmax (Mixtral: 8e top-2; Arctic: 128e top-2 with a dense
residual branch in parallel).  Dispatch is capacity-bounded scatter into
per-expert buffers — tokens over capacity are dropped (standard GShard
semantics; tests use a generous capacity_factor to compare against the dense
oracle).

Expert parallelism: experts are sharded over the mesh's ``model`` axis.  When
a MeshContext is active, the layer runs under ``shard_map``: every model-rank
computes router scores for its (replicated-over-model) local tokens, scatters
the tokens destined to *its* experts, runs the local expert matmuls, and the
partial outputs are combined with one ``psum`` over the model axis — the same
collective shape as a TP FFN all-reduce, with deterministic layout (no SPMD
partitioner guessing on scatter ops).  The cheaper all-to-all dispatch
variant is a recorded §Perf hillclimb candidate (EXPERIMENTS.md).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import compat, meshctx
from repro.models import layers as Ly
from repro.models.config import ModelConfig


def moe_init(key, cfg: ModelConfig) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    scale_in, scale_out = 1.0 / np.sqrt(D), 1.0 / np.sqrt(F)
    p = {
        "router": Ly.dense_init(ks[0], D, E, dtype=jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, D, F), jnp.float32)
                   * scale_in).astype(Ly.BF16),
        "w_up": (jax.random.normal(ks[2], (E, D, F), jnp.float32)
                 * scale_in).astype(Ly.BF16),
        "w_down": (jax.random.normal(ks[3], (E, F, D), jnp.float32)
                   * scale_out).astype(Ly.BF16),
    }
    return p


def _route(router_w, cfg: ModelConfig, x2d):
    """Top-k routing. Returns (expert_ids (T,k), combine_w (T,k))."""
    logits = jnp.dot(x2d.astype(jnp.float32), router_w)        # (T, E)
    top_vals, top_ids = jax.lax.top_k(logits, cfg.top_k)
    combine = jax.nn.softmax(top_vals, axis=-1)                # (T, k)
    return top_ids, combine


@jax.custom_vjp
def _bf16_grad(w):
    """Identity with BF16 cotangent — keeps per-layer expert weight grads
    (stacked over periods by the layer scan) out of f32 (paper §4.1:
    gradients live in BF16)."""
    return w


def _bf16_grad_fwd(w):
    return w, None


def _bf16_grad_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


_bf16_grad.defvjp(_bf16_grad_fwd, _bf16_grad_bwd)


def _expert_compute(wg, wu, wd, buf):
    """buf: (E, C, D) → (E, C, D) bf16 through per-expert SwiGLU."""
    # barrier: the CPU backend emulates bf16 dots by converting operands to
    # f32; without the barrier XLA hoists that convert out of the layer scan
    # and keeps an f32 copy of ALL stacked expert weights resident (TPU has
    # native bf16 MXU dots — no such copy).  See EXPERIMENTS.md §Dry-run.
    wg, wu, wd = compat.optimization_barrier((wg, wu, wd))
    wg, wu, wd = _bf16_grad(wg), _bf16_grad(wu), _bf16_grad(wd)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg,
                               preferred_element_type=jnp.float32))
    h = h * jnp.einsum("ecd,edf->ecf", buf, wu,
                       preferred_element_type=jnp.float32)
    out = jnp.einsum("ecf,efd->ecd", h.astype(jnp.bfloat16), wd,
                     preferred_element_type=jnp.float32)
    return out.astype(jnp.bfloat16)


def _dispatch_combine(cfg: ModelConfig, x2d, expert_ids, combine,
                      wg, wu, wd, e_lo: jax.Array, e_local: int):
    """Capacity-scatter tokens routed to experts [e_lo, e_lo+e_local),
    compute, and combine back to (T, D) (zeros for foreign experts)."""
    T, D = x2d.shape
    k = cfg.top_k
    cap = max(8, int(np.ceil(cfg.capacity_factor * k * T / cfg.n_experts)))

    flat_ids = expert_ids.reshape(-1)                    # (T*k,)
    local = flat_ids - e_lo
    mine = (local >= 0) & (local < e_local)
    safe_local = jnp.where(mine, local, 0)
    # position of each routed copy within its expert's capacity buffer
    onehot = jax.nn.one_hot(jnp.where(mine, safe_local, e_local),
                            e_local + 1, dtype=jnp.int32)  # drop row e_local
    pos = jnp.cumsum(onehot, axis=0) - 1                  # (T*k, E_local+1)
    my_pos = jnp.take_along_axis(pos, safe_local[:, None], 1)[:, 0]
    keep = mine & (my_pos < cap)
    slot = jnp.where(keep, safe_local * cap + my_pos, e_local * cap)

    src = jnp.repeat(x2d, k, axis=0).astype(jnp.bfloat16)  # (T*k, D)
    buf = jnp.zeros((e_local * cap + 1, D), jnp.bfloat16)
    buf = buf.at[slot].set(jnp.where(keep[:, None], src, 0))
    buf = buf[:-1].reshape(e_local, cap, D)

    out_buf = _expert_compute(wg, wu, wd, buf)            # (E_l, C, D) bf16
    out_flat = out_buf.reshape(e_local * cap, D)
    gathered = jnp.where(keep[:, None],
                         jnp.take(out_flat,
                                  jnp.minimum(slot, e_local * cap - 1),
                                  axis=0),
                         jnp.bfloat16(0.0))               # (T*k, D) bf16
    w = (combine.reshape(-1) * keep).astype(jnp.bfloat16)
    y = (gathered * w[:, None]).reshape(T, k, D).sum(1)
    return y.astype(jnp.bfloat16)


def _a2a_ep_body(cfg: ModelConfig, ctx, router, wg, wu, wd, xl,
                 n_data: int, n_model: int):
    """a2a expert parallelism (EXPERIMENTS.md §Perf A2): experts live 2-D
    sharded — E over the data axis (E/n_data local), F over the model axis
    (F/n_model local) — and never move.  Local tokens are routed with one
    ``all_to_all`` over data to their expert-owner rank, computed against
    the resident F-slice, psum'd over model (down-proj partials), and
    a2a'd back.  Wire bytes per layer ≈ tokens·k·D ≪ the weight-gather
    bytes the FSDP-EP baseline pays (the structural fix for arctic)."""
    T_l, D = xl.shape
    k = cfg.top_k
    e_per_data = cfg.n_experts // n_data
    # per-destination send capacity (uniform routing + slack)
    cap = max(8, int(np.ceil(cfg.capacity_factor * k * T_l / n_data)))

    ids, combine = _route(router, cfg, xl)              # (T_l, k)
    flat_e = ids.reshape(-1)                            # (T_l·k,)
    dst = flat_e // e_per_data                          # owner data-rank
    # position within each destination's send buffer
    onehot = jax.nn.one_hot(dst, n_data, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    my_pos = jnp.take_along_axis(pos, dst[:, None], 1)[:, 0]
    keep = my_pos < cap
    slot = jnp.where(keep, dst * cap + my_pos, n_data * cap)

    src_tok = jnp.repeat(xl, k, axis=0).astype(jnp.bfloat16)
    send = jnp.zeros((n_data * cap + 1, D), jnp.bfloat16)
    send = send.at[slot].set(jnp.where(keep[:, None], src_tok, 0))
    send = send[:-1].reshape(n_data, cap, D)
    send_eid = jnp.full((n_data * cap + 1,), -1, jnp.int32)
    send_eid = send_eid.at[slot].set(jnp.where(keep, flat_e, -1))
    send_eid = send_eid[:-1].reshape(n_data, cap)

    # dispatch: tiled all_to_all over the data axis (split/concat axis 0)
    rflat = jax.lax.all_to_all(send.reshape(n_data * cap, D),
                               ctx.data_axes[0], 0, 0, tiled=True)
    rid = jax.lax.all_to_all(send_eid.reshape(n_data * cap),
                             ctx.data_axes[0], 0, 0, tiled=True)
    # local expert index ∈ [0, e_per_data)
    rank_d = jax.lax.axis_index(ctx.data_axes[0])
    local_e = rid - rank_d * e_per_data
    mine = (rid >= 0) & (local_e >= 0) & (local_e < e_per_data)
    safe_e = jnp.where(mine, local_e, e_per_data)
    cap_e = max(8, int(np.ceil(cfg.capacity_factor * k * T_l * 2
                               / e_per_data / n_data)))
    oh = jax.nn.one_hot(safe_e, e_per_data + 1, dtype=jnp.int32)
    pos_e = jnp.cumsum(oh, axis=0) - 1
    my_pe = jnp.take_along_axis(pos_e, safe_e[:, None], 1)[:, 0]
    keep_e = mine & (my_pe < cap_e)
    slot_e = jnp.where(keep_e, safe_e * cap_e + my_pe, e_per_data * cap_e)
    buf = jnp.zeros((e_per_data * cap_e + 1, D), jnp.bfloat16)
    buf = buf.at[slot_e].set(jnp.where(keep_e[:, None], rflat, 0))
    buf = buf[:-1].reshape(e_per_data, cap_e, D)

    # resident F-sliced expert compute; psum over model completes down-proj
    out_buf = _expert_compute(wg, wu, wd, buf)          # partial over F
    out_buf = jax.lax.psum(out_buf.astype(jnp.float32),
                           ctx.model_axis).astype(jnp.bfloat16)

    # route back: gather per-slot outputs, reverse a2a, combine on source
    out_flat = out_buf.reshape(e_per_data * cap_e, D)
    back = jnp.where(keep_e[:, None],
                     jnp.take(out_flat,
                              jnp.minimum(slot_e, e_per_data * cap_e - 1),
                              axis=0), jnp.bfloat16(0.0))
    ret_flat = jax.lax.all_to_all(back.reshape(n_data * cap, D),
                                  ctx.data_axes[0], 0, 0, tiled=True)
    gathered = jnp.where(keep[:, None],
                         jnp.take(ret_flat,
                                  jnp.minimum(slot, n_data * cap - 1),
                                  axis=0), jnp.bfloat16(0.0))
    w = (combine.reshape(-1) * keep).astype(jnp.bfloat16)
    y = (gathered * w[:, None]).reshape(T_l, k, D).sum(1)
    return y.astype(jnp.bfloat16)


def moe_apply(p, cfg: ModelConfig, x) -> jax.Array:
    """x: (B, S, D) → (B, S, D)."""
    B, S, D = x.shape
    x2d = x.reshape(B * S, D)
    ctx = meshctx.get()

    if ctx is None or ctx.model_size == 1 or cfg.n_experts < 2:
        ids, combine = _route(p["router"], cfg, x2d)
        y = _dispatch_combine(cfg, x2d, ids, combine, p["w_gate"], p["w_up"],
                              p["w_down"], jnp.int32(0), cfg.n_experts)
        return y.astype(x.dtype).reshape(B, S, D)

    if cfg.moe_mode == "a2a":
        n_data = ctx.mesh.shape[ctx.data_axes[0]]
        n_model = ctx.model_size
        assert cfg.n_experts % n_data == 0 and cfg.d_ff % n_model == 0
        spec_w_up = P(ctx.data_axes[0], None, ctx.model_axis)
        spec_w_dn = P(ctx.data_axes[0], ctx.model_axis, None)
        n_batch = 1
        for a in ctx.batch_axes:
            n_batch *= ctx.mesh.shape[a]
        tok_spec = (P(ctx.batch_axes, None)
                    if x2d.shape[0] % n_batch == 0 else P(None, None))

        @functools.partial(
            compat.shard_map, mesh=ctx.mesh,
            in_specs=(P(None, None), spec_w_up, spec_w_up, spec_w_dn,
                      tok_spec),
            out_specs=tok_spec,
            check_vma=False)
        def a2a_body(router, wg, wu, wd, xl):
            return _a2a_ep_body(cfg, ctx, router, wg, wu, wd, xl,
                                n_data, n_model)

        y = a2a_body(p["router"], p["w_gate"], p["w_up"], p["w_down"], x2d)
        return y.astype(x.dtype).reshape(B, S, D)

    n_model = ctx.model_size
    if cfg.n_experts % n_model == 0:
        # EP: experts sharded over the model axis (arctic: 128e / 16)
        e_local = cfg.n_experts // n_model
        wg_spec = wu_spec = P(ctx.model_axis, None, None)
        wd_spec = P(ctx.model_axis, None, None)
        ep_mode = True
    else:
        # TP-inside-expert: all experts local, FFN hidden dim sharded
        # (mixtral: 8e with model=16 → F/16 slices, psum after down-proj)
        assert cfg.d_ff % n_model == 0, \
            f"{cfg.name}: neither E={cfg.n_experts} nor F={cfg.d_ff} " \
            f"divisible by model axis {n_model}"
        e_local = cfg.n_experts
        wg_spec = wu_spec = P(None, None, ctx.model_axis)
        wd_spec = P(None, ctx.model_axis, None)
        ep_mode = False

    # decode cells can have fewer tokens than data shards (batch=1 long-
    # context): fall back to replicated tokens (compute is tiny there)
    n_batch = 1
    for a in ctx.batch_axes:
        n_batch *= ctx.mesh.shape[a]
    tok_spec = P(ctx.batch_axes, None) if x2d.shape[0] % n_batch == 0 \
        else P(None, None)

    @functools.partial(
        compat.shard_map, mesh=ctx.mesh,
        in_specs=(P(None, None),                    # router (replicated)
                  wg_spec, wu_spec, wd_spec,
                  tok_spec),                        # tokens
        out_specs=tok_spec,
        check_vma=False)
    def moe_body(router, wg, wu, wd, xl):
        ids, combine = _route(router, cfg, xl)
        if ep_mode:
            rank = jax.lax.axis_index(ctx.model_axis)
            e_lo = rank * e_local
        else:
            e_lo = jnp.int32(0)
        y = _dispatch_combine(cfg, xl, ids, combine, wg, wu, wd,
                              e_lo, e_local)
        # EP: sums expert-shard contributions; TP: sums F-slice partials
        return jax.lax.psum(y, ctx.model_axis)

    y = moe_body(p["router"], p["w_gate"], p["w_up"], p["w_down"], x2d)
    return y.astype(x.dtype).reshape(B, S, D)
