"""Stub modality frontends (per task spec: ``[audio]``/``[vlm]`` entries are
backbone-only; ``input_specs()`` provides precomputed frame/patch embeddings).

* ``audio_frames`` (musicgen): EnCodec is NOT run — the model consumes
  precomputed frame embeddings (B, S, D_FRONTEND) summed over codebooks,
  projected to d_model.  Targets are next-frame codebook-0 codes (vocab 2048).
* ``vision`` (llama-3.2-vision): the ViT tower is NOT run — precomputed patch
  embeddings (B, N_PATCHES, D_FRONTEND) are projected and fed to the gated
  cross-attention layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as Ly
from repro.models.config import ModelConfig

D_FRONTEND = {"audio_frames": 512, "vision": 1280}


def frontend_init(key, cfg: ModelConfig) -> dict:
    if cfg.frontend is None:
        return {}
    d_in = D_FRONTEND[cfg.frontend]
    return {"proj": Ly.dense_init(key, d_in, cfg.d_model)}


def frontend_apply(p, cfg: ModelConfig, embeds: jax.Array) -> jax.Array:
    return Ly.dense(p["proj"], embeds.astype(jnp.bfloat16))
