"""f32 AdamW (oracle) and Renee-style mixed-precision AdamW (baseline).

``mpt_adamw`` reproduces what the paper criticizes (§3, Fig. 1): f32 master
weights + ephemeral low-precision compute copies + loss-scaled low-precision
gradients upcast to f32 for the update.  It exists so benchmarks can measure
the memory/stability gap against ELMO's pure-low-precision recipe.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


class AdamWState(NamedTuple):
    m: jax.Array
    v: jax.Array


def adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    def init(params):
        return jax.tree.map(
            lambda p: AdamWState(jnp.zeros_like(p, jnp.float32),
                                 jnp.zeros_like(p, jnp.float32)), params,
            is_leaf=lambda x: isinstance(x, jax.Array))

    def update(params, state, grads, step, lr):
        t = step.astype(jnp.float32) + 1.0
        bc1, bc2 = 1.0 - b1 ** t, 1.0 - b2 ** t

        def upd(p, s, g):
            g32 = g.astype(jnp.float32)
            m = s.m * b1 + (1 - b1) * g32
            v = s.v * b2 + (1 - b2) * g32 * g32
            delta = -lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                           + weight_decay * p.astype(jnp.float32))
            return (p.astype(jnp.float32) + delta).astype(p.dtype), \
                AdamWState(m, v)

        flat_p, treedef = jax.tree.flatten(params)
        flat_s = treedef.flatten_up_to(state)
        flat_g = treedef.flatten_up_to(grads)
        out = [upd(p, s, g) for p, s, g in zip(flat_p, flat_s, flat_g)]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    return Optimizer(init=init, update=update, name="adamw")


class MPTState(NamedTuple):
    master: jax.Array        # f32 master copy (the memory cost Renee pays)
    m: jax.Array
    v: jax.Array
    loss_scale: jax.Array    # dynamic loss scale (FP16-era machinery)
    good_steps: jax.Array


def mpt_adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
              weight_decay: float = 0.01, init_scale: float = 2.0 ** 16,
              growth_interval: int = 2000) -> Optimizer:
    """FP16-style MPT: params are the *low-precision* copies; the state holds
    f32 masters.  ``grads`` are expected pre-multiplied by ``loss_scale``;
    non-finite grads skip the step and halve the scale (torch.amp semantics).
    """

    def init(params):
        def mk(p):
            return MPTState(p.astype(jnp.float32),
                            jnp.zeros(p.shape, jnp.float32),
                            jnp.zeros(p.shape, jnp.float32),
                            jnp.float32(init_scale), jnp.int32(0))
        return jax.tree.map(mk, params,
                            is_leaf=lambda x: isinstance(x, jax.Array))

    def update(params, state, grads, step, lr):
        t = step.astype(jnp.float32) + 1.0
        bc1, bc2 = 1.0 - b1 ** t, 1.0 - b2 ** t

        def upd(p, s, g):
            g32 = g.astype(jnp.float32) / s.loss_scale
            finite = jnp.isfinite(g32).all()
            m = jnp.where(finite, s.m * b1 + (1 - b1) * g32, s.m)
            v = jnp.where(finite, s.v * b2 + (1 - b2) * g32 * g32, s.v)
            delta = -lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                           + weight_decay * s.master)
            master = jnp.where(finite, s.master + delta, s.master)
            good = jnp.where(finite, s.good_steps + 1, 0)
            scale = jnp.where(
                finite,
                jnp.where(good >= growth_interval, s.loss_scale * 2.0,
                          s.loss_scale),
                s.loss_scale * 0.5)
            good = jnp.where(good >= growth_interval, 0, good)
            return master.astype(p.dtype), MPTState(master, m, v, scale, good)

        flat_p, treedef = jax.tree.flatten(params)
        flat_s = treedef.flatten_up_to(state)
        flat_g = treedef.flatten_up_to(grads)
        out = [upd(p, s, g) for p, s, g in zip(flat_p, flat_s, flat_g)]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    return Optimizer(init=init, update=update, name="mpt_adamw")
