"""Pure-BF16 AdamW with Kahan-compensated updates (paper §4.1).

Parameters, moments, and the compensation buffer are all stored BF16 — no
f32 master copy (that is the whole point vs. mixed-precision training).
Arithmetic is f32 inside the step.  Memory per parameter: 2 (p) + 2 (m) +
2 (v) + 2 (c) = 8 bytes, vs. 16 for f32 AdamW w/ bf16 copy (see
core/memory_model.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import precision as P
from repro.optim.base import Optimizer


class KahanAdamWState(NamedTuple):
    m: jax.Array
    v: jax.Array
    comp: jax.Array


def kahan_adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                weight_decay: float = 0.01,
                store_dtype=P.BF16) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, store_dtype)
        return jax.tree.map(
            lambda p: KahanAdamWState(zeros(p), zeros(p), zeros(p)), params,
            is_leaf=lambda x: isinstance(x, jax.Array))

    def update(params, state, grads, step, lr):
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, s, g):
            g32 = g.astype(jnp.float32)
            m32 = s.m.astype(jnp.float32) * b1 + (1.0 - b1) * g32
            v32 = s.v.astype(jnp.float32) * b2 + (1.0 - b2) * g32 * g32
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = -lr * (mhat / (jnp.sqrt(vhat) + eps)
                           + weight_decay * p.astype(jnp.float32))
            p_new, c_new = P.kahan_update(p, s.comp, delta)
            return p_new, KahanAdamWState(m32.astype(store_dtype),
                                          v32.astype(store_dtype), c_new)

        flat_p, treedef = jax.tree.flatten(params)
        flat_s = treedef.flatten_up_to(state)
        flat_g = treedef.flatten_up_to(grads)
        out = [upd(p, s, g) for p, s, g in zip(flat_p, flat_s, flat_g)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_s = treedef.unflatten([o[1] for o in out])
        return new_p, new_s

    return Optimizer(init=init, update=update, name="kahan_adamw")
