"""Momentum-free SGD with stochastic rounding (paper §4.1–4.2).

The classifier-side optimizer: zero state (momentum removed, §4.2), updates
applied with SR so sub-ulp steps make progress in BF16/E4M3 storage.  The
ELMO head normally applies this *fused* inside the Pallas update kernel;
this standalone version covers non-fused tensors (and the beyond-paper
option of giving giant MoE expert weights the same treatment).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import precision as P
from repro.kernels import prng_utils as PR
from repro.optim.base import Optimizer, leaf_seed


def _sr_apply(p_new32: jax.Array, dtype, seed: jax.Array) -> jax.Array:
    # N-D hash: elementwise, preserves sharding (a flatten here would force
    # XLA to gather giant sharded parameters — see EXPERIMENTS.md §Dry-run)
    bits = PR.hash_bits_nd(seed, p_new32.shape)
    if jnp.dtype(dtype) == jnp.dtype(P.BF16):
        return P.sr_bits_bf16(p_new32, bits)
    if jnp.dtype(dtype) == jnp.dtype(P.E4M3):
        return P.sr_bits_e4m3(p_new32, bits)
    return p_new32.astype(dtype)


# leaves above this element count are updated chunk-by-chunk over their
# leading (period-stack) axis — the paper's chunking idea applied to the
# optimizer, bounding f32/bits temporaries to one slice at a time
_CHUNKED_UPDATE_ELEMS = 1 << 27


def sgd_sr(weight_decay: float = 0.0, use_sr: bool = True) -> Optimizer:
    def init(params):
        return ()  # stateless — the paper's memory point

    def _one(p, g, lr, seed):
        # barrier: stops XLA from commuting this convert with the chunk
        # dynamic-slice and hoisting a full-tensor f32 copy out of the loop
        p, g = jax.lax.optimization_barrier((p, g))
        p32 = p.astype(jnp.float32)
        p_new = p32 * (1.0 - lr * weight_decay) - lr * g.astype(jnp.float32)
        if use_sr and p.dtype in (jnp.dtype(P.BF16), jnp.dtype(P.E4M3)):
            return _sr_apply(p_new, p.dtype, seed)
        return p_new.astype(p.dtype)

    def update(params, state, grads, step, lr):
        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        out = []
        for i, (p, g) in enumerate(zip(flat_p, flat_g)):
            seed = leaf_seed(i, step)
            if p.size > _CHUNKED_UPDATE_ELEMS and p.ndim >= 2 \
                    and p.shape[0] > 1:
                def body(_, inp):
                    pj, gj, j = inp
                    return None, _one(pj, gj, lr,
                                      seed + j.astype(jnp.uint32))
                _, p_new = jax.lax.scan(
                    body, None,
                    (p, g, jnp.arange(p.shape[0], dtype=jnp.int32)))
                out.append(p_new)
            else:
                out.append(_one(p, g, lr, seed))
        return treedef.unflatten(out), state

    return Optimizer(init=init, update=update, name="sgd_sr")
