"""Partitioned optimizer: route parameter subsets to different optimizers.

Beyond-paper extension (DESIGN.md §3, arctic-480b): at 469B expert
parameters, AdamW-with-Kahan costs 8 bytes/param of optimizer state.  The
ELMO recipe for the *classifier* — momentum-free SGD + stochastic rounding,
zero state (§4.2) — applies verbatim to any parameter block whose memory
dominates, so expert weights get ``sgd_sr`` while the (tiny) attention /
norm / router parameters keep Kahan-AdamW.
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.optim.base import Optimizer


def partitioned(route: Callable[[str], str], opts: dict[str, Optimizer]
                ) -> Optimizer:
    """``route(path_string) -> key in opts``; each group steps independently."""

    def _paths(tree):
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", getattr(
            k, "name", k)))) for k in path) for path, _ in flat]
        return paths, [leaf for _, leaf in flat], treedef

    def _split(tree):
        paths, leaves, treedef = _paths(tree)
        groups = {name: [] for name in opts}
        for p, leaf in zip(paths, leaves):
            for name in opts:
                groups[name].append(leaf if route(p) == name else None)
        return groups, treedef

    def _mask_tree(treedef, leaves):
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def init(params):
        groups, treedef = _split(params)
        states = {}
        for name, opt in opts.items():
            # masked leaves become empty states; store per-group full trees
            masked = _mask_tree(
                treedef, [l if l is not None else jax.numpy.zeros((0,))
                          for l in groups[name]])
            states[name] = opt.init(masked)
        return states

    def update(params, state, grads, step, lr):
        paths, p_leaves, treedef = _paths(params)
        g_leaves = treedef.flatten_up_to(grads)
        out_leaves = list(p_leaves)
        new_states = {}
        for name, opt in opts.items():
            sel = [i for i, p in enumerate(paths) if route(p) == name]
            if not sel:
                new_states[name] = state[name]
                continue
            sub_p = _mask_tree(
                treedef, [p_leaves[i] if i in set(sel)
                          else jax.numpy.zeros((0,)) for i in range(len(paths))])
            sub_g = _mask_tree(
                treedef, [g_leaves[i] if i in set(sel)
                          else jax.numpy.zeros((0,)) for i in range(len(paths))])
            new_p, new_s = opt.update(sub_p, state[name], sub_g, step, lr)
            new_p_leaves = treedef.flatten_up_to(new_p)
            for i in sel:
                out_leaves[i] = new_p_leaves[i]
            new_states[name] = new_s
        return jax.tree_util.tree_unflatten(treedef, out_leaves), new_states

    return Optimizer(init=init, update=update, name="partitioned")


def expert_route(path: str) -> str:
    """arctic-480b routing: giant MoE expert tensors → ELMO SGD-SR."""
    return "expert" if ("moe" in path and "router" not in path) else "base"
