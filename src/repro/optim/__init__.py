"""Pure-pytree optimizers (no optax dependency).

* ``kahan_adamw`` — pure-BF16 AdamW with Kahan-compensated parameter updates
  (paper §4.1: the encoder optimizer; optimi-style).
* ``sgd_sr``      — momentum-free SGD with stochastic rounding (paper §4.2:
  the classifier optimizer, for non-fused tensors).
* ``adamw``       — plain f32 AdamW (oracle/baseline) and an "mpt" variant
  with f32 master weights + low-precision compute copies (Renee-style).
"""
from repro.optim.adamw import adamw, mpt_adamw
from repro.optim.kahan_adamw import kahan_adamw
from repro.optim.schedules import linear_warmup_cosine, linear_warmup_constant
from repro.optim.sgd_sr import sgd_sr

__all__ = ["adamw", "mpt_adamw", "kahan_adamw", "sgd_sr",
           "linear_warmup_cosine", "linear_warmup_constant"]
