"""Learning-rate schedules (paper Table 9 uses linear warmup + constant)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_constant(base_lr: float, warmup_steps: int):
    def schedule(step):
        s = step.astype(jnp.float32)
        w = jnp.minimum(1.0, (s + 1.0) / max(warmup_steps, 1))
        return jnp.float32(base_lr) * w
    return schedule


def linear_warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                         final_frac: float = 0.1):
    def schedule(step):
        s = step.astype(jnp.float32)
        w = jnp.minimum(1.0, (s + 1.0) / max(warmup_steps, 1))
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.float32(base_lr) * w * cos
    return schedule
