"""Optimizer protocol: a pair of pure functions over parameter pytrees.

    init(params)                          -> state
    update(params, state, grads, step, lr) -> (new_params, new_state)

``step`` is a 0-d int32; ``lr`` a 0-d f32 (schedules live outside).  All
optimizers are jit/pjit-compatible and donate-friendly (states are pytrees of
arrays with stable treedefs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax

Params = Any
State = Any
Grads = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], State]
    update: Callable[[Params, State, Grads, jax.Array, jax.Array],
                     Tuple[Params, State]]
    name: str = "optimizer"


def leaf_seed(path_index: int, step: jax.Array) -> jax.Array:
    """Deterministic per-leaf, per-step PRNG seed for SR optimizers."""
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import prng_utils as PR
    return PR.mix32(step.astype(jnp.uint32) * np.uint32(0x9E3779B9)
                    + np.uint32(path_index * 7919 + 1))
