"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
we build ShapeDtypeStruct stand-ins (no allocation), jit-lower the step
function under the production mesh, compile, and record
``memory_analysis()`` (fits/doesn't), ``cost_analysis()`` (FLOPs/bytes for
§Roofline) and the collective-operand bytes parsed from the
post-partitioning HLO.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b \
        --shape train_4k --mesh single          # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json

Results are appended incrementally to the JSON report so a crash loses one
cell, not the run.
"""
# The VERY FIRST two lines, before ANY other import (jax locks device count
# on first init):
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config                        # noqa: E402
from repro.configs.registry import (ARCHS, SHAPES, cell_applicable,  # noqa: E402
                                    input_specs)
from repro.dist import compat as Compat                     # noqa: E402
from repro.head import (default_target_slots, head_config_for,  # noqa: E402
                        resolve_plan)
from repro.dist import meshctx, sharding as Sh              # noqa: E402
from repro.launch import steps as St                        # noqa: E402
from repro.launch.mesh import make_context                  # noqa: E402
from repro.models import transformer as T                   # noqa: E402
from repro.optim import kahan_adamw                         # noqa: E402
from repro.optim.partitioned import expert_route, partitioned  # noqa: E402
from repro.optim.sgd_sr import sgd_sr                       # noqa: E402

GIB = 1024 ** 3

# ---------------------------------------------------------------------------
# collective-bytes extraction from post-SPMD HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "u32": 4, "s32": 4,
                "f8e4m3fn": 1, "f8e5m2": 1, "u8": 1, "s8": 1, "u16": 2,
                "s16": 2, "pred": 1, "u64": 8, "s64": 8}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?\S+\s*=\s*(\(?[^)=]*\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op, by kind (per device —
    the HLO is post-partitioning so shapes are local shards)."""
    out: dict = {}
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        if "-done(" in line:      # avoid double-counting async pairs
            continue
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0) + b
        out["total"] = out.get("total", 0) + b
    return out


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------


def _sds(tree, mesh, spec_tree):
    """ShapeDtypeStructs with shardings from (abstract) value tree + specs.
    Specs are sanitized against actual dim divisibility (e.g. batch=1)."""
    def mk(leaf, spec):
        if leaf is None:
            return None
        spec = Sh.sanitize_spec(leaf.shape, spec if spec is not None else P(),
                                mesh)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(mk, tree, spec_tree,
                        is_leaf=lambda x: x is None or isinstance(
                            x, (jax.ShapeDtypeStruct, P)))


def _shardings_of(sds_tree):
    """Extract the NamedSharding tree from a ShapeDtypeStruct tree."""
    return jax.tree.map(lambda x: x.sharding if x is not None else None,
                        sds_tree,
                        is_leaf=lambda x: x is None or isinstance(
                            x, jax.ShapeDtypeStruct))


def _rep(mesh):
    return NamedSharding(mesh, P())


def make_optimizer(arch: str):
    if arch == "arctic-480b":   # ELMO treatment for 469B expert params
        return partitioned(expert_route, {"expert": sgd_sr(use_sr=True),
                                          "base": kahan_adamw()})
    return kahan_adamw()


def lower_train_cell(cfg, shape, ctx):
    opt = make_optimizer(cfg.name)
    state_abs = jax.eval_shape(
        lambda k: St.init_train_state(k, cfg, opt, impl="xla"),
        jax.random.PRNGKey(0))
    n_model = ctx.model_size
    n_data = ctx.mesh.shape["data"]        # FSDP axis (pods stay pure DP)
    bspec = Sh.backbone_specs(cfg, state_abs.backbone, n_model, n_data)
    state_specs = St.TrainState(
        backbone=bspec,
        opt_state=Sh.opt_state_specs(bspec, state_abs.opt_state),
        head=Sh.head_specs(cfg, n_model),
        step=P())
    state_sds = _sds(state_abs, ctx.mesh, state_specs)

    raw = input_specs(cfg, shape)
    bspecs = Sh.batch_specs(cfg, ctx.batch_axes)
    batch_sds = {k: _sds(v, ctx.mesh, bspecs[k]) for k, v in raw.items()}

    def step(state, batch):
        return St.train_step(cfg, opt, state, batch,
                             head_lr=jnp.float32(0.05),
                             backbone_lr=jnp.float32(2e-5), impl="xla")

    # out_shardings pinned to the input state shardings: guarantees donation
    # aliasing and stops XLA from materializing updated weights replicated
    metrics_sh = {"loss": _rep(ctx.mesh), "xgrad_norm": _rep(ctx.mesh),
                  "step": _rep(ctx.mesh)}
    return jax.jit(step, donate_argnums=(0,),
                   out_shardings=(_shardings_of(state_sds), metrics_sh)
                   ).lower(state_sds, batch_sds)


def lower_decode_cell(cfg, shape, ctx):
    state_abs = jax.eval_shape(
        lambda k: St.init_serve_state(k, cfg, shape.batch, shape.seq,
                                      impl="xla"),
        jax.random.PRNGKey(0))
    n_model = ctx.model_size
    n_data = ctx.mesh.shape["data"]   # weight-gathered serving (FSDP specs)
    specs = St.ServeState(
        backbone=Sh.backbone_specs(cfg, state_abs.backbone, n_model, n_data),
        head=Sh.head_specs(cfg, n_model),
        caches=Sh.cache_specs(cfg, state_abs.caches, ctx.batch_axes, n_model))
    state_sds = _sds(state_abs, ctx.mesh, specs)

    raw = input_specs(cfg, shape)
    tok_sds = _sds(raw["token"], ctx.mesh, P(ctx.batch_axes, None))
    fe = raw.get("frontend_embeds")
    fe_sds = (_sds(fe, ctx.mesh, P(ctx.batch_axes, None, None))
              if fe is not None else None)

    def step(state, token, fe_in):
        return St.serve_decode(cfg, state, token, fe_in, impl="xla")

    tok_out = NamedSharding(ctx.mesh, Sh.sanitize_spec(
        (shape.batch,), P(ctx.batch_axes), ctx.mesh))
    return jax.jit(step, donate_argnums=(0,),
                   out_shardings=(tok_out, _shardings_of(state_sds))
                   ).lower(state_sds, tok_sds, fe_sds)


def lower_prefill_cell(cfg, shape, ctx):
    state_abs = jax.eval_shape(
        lambda k: St.init_serve_state(k, cfg, shape.batch, shape.seq,
                                      impl="xla"),
        jax.random.PRNGKey(0))
    n_model = ctx.model_size
    n_data = ctx.mesh.shape["data"]   # weight-gathered serving (FSDP specs)
    specs = St.ServeState(
        backbone=Sh.backbone_specs(cfg, state_abs.backbone, n_model, n_data),
        head=Sh.head_specs(cfg, n_model),
        caches=Sh.cache_specs(cfg, state_abs.caches, ctx.batch_axes, n_model))
    state_sds = _sds(state_abs, ctx.mesh, specs)

    raw = input_specs(cfg, shape)
    bspecs = Sh.batch_specs(cfg, ctx.batch_axes)
    in_sds = {k: _sds(v, ctx.mesh, bspecs[k]) for k, v in raw.items()}

    def step(state, inputs):
        return St.serve_prefill(cfg, state, inputs["tokens"],
                                inputs.get("frontend_embeds"), impl="xla")

    tok_out = NamedSharding(ctx.mesh, Sh.sanitize_spec(
        (shape.batch,), P(ctx.batch_axes), ctx.mesh))
    return jax.jit(step, donate_argnums=(0,),
                   out_shardings=(tok_out, _shardings_of(state_sds))
                   ).lower(state_sds, in_sds)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if overrides:
        rec["overrides"] = overrides
    skip = cell_applicable(cfg, shape)
    if skip:
        rec["skipped"] = skip
        return rec
    t0 = time.time()
    ctx = make_context(multi_pod=multi_pod)
    if cfg.sharding_strategy == "fsdp_pure" and shape.kind == "train":
        # batch over (data × model); params FSDP over both; no TP/SP
        ctx = dataclasses.replace(ctx, data_axes=("data", "model"))
    elif cfg.sharding_strategy == "fsdp_pure":
        # serving keeps TP: per-token weight gathers would be absurd
        cfg = dataclasses.replace(cfg, sharding_strategy="tp_sp")
    with meshctx.use(ctx):
        if shape.kind == "train":
            # record the resolved HeadPlan next to the measured numbers so
            # predicted-vs-compiled drift is visible per cell.  The head
            # steps one MICRObatch at a time under grad accumulation, so
            # the plan is resolved at the microbatch the step executes.
            hcfg = head_config_for(cfg, impl="xla")
            mb = shape.batch // max(1, cfg.grad_accum)
            plan = resolve_plan(
                hcfg, batch=(mb if cfg.pool == "first" else mb * shape.seq),
                target_slots=default_target_slots(cfg),
                model_size=ctx.model_size, model_axis=ctx.model_axis)
            rec["head_plan"] = {
                "path": plan.path, "inner": plan.train_inner,
                "block_l": plan.block_l, "cache_z": plan.cache_z,
                "temp_bytes": plan.temp_bytes,
                "fallback": plan.fallback_reason}
            lowered = lower_train_cell(cfg, shape, ctx)
        elif shape.kind == "prefill":
            lowered = lower_prefill_cell(cfg, shape, ctx)
        else:
            lowered = lower_decode_cell(cfg, shape, ctx)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_gib": mem.argument_size_in_bytes / GIB,
            "output_gib": mem.output_size_in_bytes / GIB,
            "temp_gib": mem.temp_size_in_bytes / GIB,
            "alias_gib": mem.alias_size_in_bytes / GIB,
            "peak_per_device_gib":
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / GIB,
        }
        cost = Compat.cost_analysis(compiled)
        rec["cost"] = {k: cost.get(k, 0.0)
                       for k in ("flops", "bytes accessed", "transcendentals")}
        rec["collectives"] = collective_bytes(compiled.as_text())
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="dryrun_report.json")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    report = []
    if os.path.exists(args.out):
        report = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in report}

    for arch, shape in cells:
        for mp in meshes:
            key = (arch, shape, "2x16x16" if mp else "16x16")
            if key in done:
                continue
            print(f"=== {arch} × {shape} × {key[2]} ===", flush=True)
            try:
                rec = run_cell(arch, shape, mp)
            except Exception as e:  # noqa: BLE001 — record and continue
                rec = {"arch": arch, "shape": shape, "mesh": key[2],
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
            print(json.dumps({k: v for k, v in rec.items() if k != "trace"},
                             indent=1), flush=True)
            report.append(rec)
            json.dump(report, open(args.out, "w"), indent=1)

    ok = sum(1 for r in report if "memory" in r)
    sk = sum(1 for r in report if "skipped" in r)
    err = sum(1 for r in report if "error" in r)
    print(f"\n==== dry-run: {ok} compiled, {sk} skipped, {err} errors ====")


if __name__ == "__main__":
    main()
