"""Serving driver: batched prefill + greedy decode, the batched top-k
serving bench (padded-bucket microbatching over the streaming kernel
path), and the deadline-aware serving runtime (``repro.serve``,
DESIGN.md §12).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --batch 4 --prompt-len 16 --gen 8
    PYTHONPATH=src python -m repro.launch.serve --arch xmc-bert-3m --smoke \
        --bench --batch 64 --k 5 --queries 256
    PYTHONPATH=src python -m repro.launch.serve --arch xmc-bert-3m --smoke \
        --serve --batch 16 --k 5 --rate 500 --burst-rate 4000 --slo-ms 50
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import head as RH
from repro.configs import get_config, get_smoke
from repro.launch import steps as St


def serve(cfg, *, batch: int, prompt_len: int, gen: int, impl: str = "auto",
          verbose_plan: bool = False):
    state = St.init_serve_state(jax.random.PRNGKey(0), cfg, batch,
                                max_len=prompt_len + gen, impl=impl)
    if verbose_plan:   # serving decisions (grid logits / top-k path)
        print(RH.get_head(St.make_head_cfg(cfg, impl),
                          batch=batch).plan.explain(), flush=True)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                         jnp.int32)
    fe_prompt = fe_step = None
    if cfg.frontend == "audio_frames":
        fe_prompt = jnp.asarray(rng.standard_normal(
            (batch, prompt_len, 512), np.float32), jnp.bfloat16)
        fe_step = jnp.zeros((batch, 1, 512), jnp.bfloat16)
    elif cfg.frontend == "vision":
        fe_prompt = fe_step = jnp.asarray(rng.standard_normal(
            (batch, cfg.n_frontend_tokens, 1280), np.float32), jnp.bfloat16)

    t0 = time.time()
    tok, state = St.serve_prefill(cfg, state, tokens, fe_prompt, impl=impl)
    prefill_s = time.time() - t0
    out = [np.asarray(tok)]
    t1 = time.time()
    for _ in range(gen - 1):
        tok, state = St.serve_decode(cfg, state, tok[:, None], fe_step,
                                     impl=impl)
        out.append(np.asarray(tok))
    decode_s = time.time() - t1
    seqs = np.stack(out, axis=1)
    return seqs, {"prefill_s": prefill_s,
                  "decode_tok_s": batch * (gen - 1) / max(decode_s, 1e-9)}


def _buckets(sizes, max_batch: int):
    """Pad each ragged query-group size up to a power-of-two bucket
    (≤ max_batch): one compiled top-k program per bucket instead of one
    per distinct batch size.  Delegates to ``serve.batcher.bucket_for``
    — the runtime's batcher and this bench must agree on bucket shapes
    or the runtime would JIT programs the bench never measured."""
    from repro.serve.batcher import bucket_for
    return [bucket_for(s, max_batch) for s in sizes]


def topk_bench(cfg, *, batch: int, k: int, queries: int, impl: str = "auto",
               seed: int = 0, verbose_plan: bool = False,
               shortlist: str = "off") -> dict:
    """Batched top-k serving bench: padded-bucket microbatching over
    ``ELMOHead.topk``.

    Queries arrive in ragged groups; each group is padded up to a
    power-of-two bucket so only O(log batch) programs compile, and the
    report carries queries/sec plus the per-query HBM traffic of the
    streaming kernel path: the whole W stream (1 byte/elem FP8)
    amortized over the bucket, + x in, + the (B, k) result out — the
    logits never touch HBM.  (Donating the query buffer would be a
    no-op: no output can alias a (B, D) bf16 donor — the results are
    (B, k) f32/int32 — so XLA would warn and copy; the loop instead just
    drops each batch after its call.)

    ``shortlist`` ∈ {off, on, auto} rewires the head config for 2-stage
    shortlisted serving (DESIGN.md §11): when the plan resolves
    ``topk_path == "shortlist"`` the bench builds + attaches an index
    from the served weights and additionally reports recall@{1,5,k} of
    shortlisted vs exact results on a held-out query batch.  Recall
    reflects the cluster structure of the SERVED head: on a trained XMC
    head (or the golden structured fixture) it clears 0.95; on this
    driver's random-init smoke weights it is necessarily near
    beam·⌈L/C⌉/L — a routing sanity number, not a quality claim."""
    import dataclasses

    head_cfg = St.make_head_cfg(cfg, impl)
    if shortlist != "off":
        head_cfg = dataclasses.replace(head_cfg, shortlist=shortlist)
    head = RH.get_head(head_cfg, batch=batch)
    if verbose_plan:
        print(head.plan.explain(), flush=True)
    state = head.init(jax.random.PRNGKey(0))
    if head.plan.topk_path == "shortlist":
        head.build_shortlist(state, iters=4)
    rng = np.random.default_rng(seed)

    @functools.partial(jax.jit, static_argnames=("b",))
    def run(s, x, b):
        del b   # static key: one program per bucket width
        return head.topk(s, x, k)

    n_groups = max(1, queries // max(1, batch // 2))
    sizes = rng.integers(1, batch + 1, size=n_groups)
    buckets = _buckets(sizes, batch)
    xs = [jnp.asarray(rng.standard_normal((b, cfg.d_model)), jnp.bfloat16)
          for b in buckets]
    for x, b in zip(xs, buckets):           # warm up every bucket program
        jax.block_until_ready(run(state, x, b=b))
    t0 = time.time()
    for x, b in zip(xs, buckets):
        vals, ids = run(state, x, b=b)
    jax.block_until_ready((vals, ids))
    dt = max(time.time() - t0, 1e-9)
    # per-bucket dispatch latency: a second, per-call-blocking pass
    # (the qps loop above stays free-running so pipelining is measured)
    from repro.serve.metrics import percentile
    lat = {}
    for x, b in zip(xs, buckets):
        t = time.time()
        jax.block_until_ready(run(state, x, b=b))
        lat.setdefault(b, []).append((time.time() - t) * 1e3)
    bucket_latency_ms = {
        int(b): {"p50": round(percentile(v, 50), 4),
                 "p95": round(percentile(v, 95), 4),
                 "calls": len(v)}
        for b, v in sorted(lat.items())}

    n_q = int(np.sum(sizes))
    n_padded = int(np.sum(buckets))
    w_bytes = int(np.prod(state.w.shape)) * jnp.dtype(state.w.dtype).itemsize
    per_query_hbm = (w_bytes / max(1, min(buckets))
                     + cfg.d_model * 2 + k * 8)
    recall = None
    if head.shortlist is not None:
        from repro.head import shortlist as _sl
        xq = jnp.asarray(rng.standard_normal((batch, cfg.d_model)),
                         jnp.bfloat16)
        recall = _sl.shortlist_recall_at_k(
            head.cfg, state, head.shortlist, xq,
            ks=sorted({1, 5, k}), impl="xla")
    return {
        "queries": n_q, "padded_rows": n_padded, "k": k,
        "topk_path": head.plan.topk_path,
        "qps": n_q / dt, "wall_s": dt,
        "per_query_hbm_bytes": int(per_query_hbm),
        "w_bytes": w_bytes,
        "bucket_sizes": sorted(set(buckets)),
        "bucket_latency_ms": bucket_latency_ms,
        "shortlist_c": head.plan.shortlist_c,
        "shortlist_beam": head.plan.shortlist_beam,
        "recall": recall,
    }


def serve_runtime(cfg, *, batch: int, k: int, rate_qps: float,
                  burst_qps: float, horizon_s: float, slo_s: float,
                  seed: int = 0, impl: str = "auto",
                  real_clock: bool = False,
                  verbose_plan: bool = False) -> dict:
    """Drive the deadline-aware serving runtime (``repro.serve``,
    DESIGN.md §12) against the real head: build the plan-gated
    degradation ladder from the served weights, warm every (bucket, k,
    level) program, replay a seeded open-loop Poisson trace (steady →
    burst → recovery), and return the metrics report.

    Default is a ``VirtualClock`` with model timing — results are real
    head outputs but the timeline is deterministic, so the same trace
    prints the same report anywhere.  ``real_clock=True`` serves the
    trace in wall time with measured service times instead."""
    from repro import serve as RS
    from repro.fault import inject as FI

    head_cfg = St.make_head_cfg(cfg, impl)
    head = RH.get_head(head_cfg, batch=batch)
    if verbose_plan:
        print(head.plan.explain(), flush=True)
    state = head.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    probe_x = jnp.asarray(rng.standard_normal((batch, cfg.d_model)),
                          jnp.bfloat16)
    levels = RS.build_ladder(head, state, k=k, max_batch=batch,
                             probe_x=probe_x, seed=seed)
    ex = RS.HeadExecutor(state,
                         timing="measure" if real_clock else "model")
    buckets = sorted({_b for _b in (1, 2, 4, 8, 16, 32, 64, 128)
                      if _b <= batch} | {batch})
    ex.warmup(levels, buckets, (k,), cfg.d_model)
    scfg = RS.ServeConfig(max_batch=batch, max_queue=16 * batch,
                          slo_s=slo_s, seed=seed)
    clock = RS.RealClock() if real_clock else RS.VirtualClock()
    srv = RS.Server(ex, levels, clock=clock, cfg=scfg)
    base = FI.poisson_requests(
        rate_qps=rate_qps, horizon_s=horizon_s, seed=seed,
        d_model=cfg.d_model, k=k, deadline_s=slo_s)
    burst = FI.poisson_requests(
        rate_qps=burst_qps, horizon_s=horizon_s / 2, seed=seed + 1,
        d_model=cfg.d_model, k=k, deadline_s=slo_s,
        t0=horizon_s, rid0=len(base))
    cool = FI.poisson_requests(
        rate_qps=rate_qps, horizon_s=horizon_s, seed=seed + 2,
        d_model=cfg.d_model, k=k, deadline_s=slo_s,
        t0=1.5 * horizon_s, rid0=len(base) + len(burst))
    rep = RS.run_trace(srv, base + burst + cool).report()
    rep["ladder"] = [repr(lv) for lv in levels]
    rep["clock"] = "real" if real_clock else "virtual"
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--plan", action="store_true",
                    help="print the resolved HeadPlan before serving")
    ap.add_argument("--bench", action="store_true",
                    help="batched top-k serving bench (padded-bucket "
                         "microbatching over the streaming kernel path; "
                         "per-bucket p50/p95 dispatch latency)")
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--serve", action="store_true",
                    help="deadline-aware serving runtime (repro.serve): "
                         "Poisson steady/burst/recovery trace through "
                         "continuous batching, admission control, and "
                         "the plan-gated degradation ladder")
    ap.add_argument("--rate", type=float, default=500.0,
                    help="--serve steady arrival rate (requests/s)")
    ap.add_argument("--burst-rate", type=float, default=4000.0,
                    help="--serve overload-burst arrival rate")
    ap.add_argument("--horizon", type=float, default=1.0,
                    help="--serve steady-segment length (virtual s)")
    ap.add_argument("--slo-ms", type=float, default=50.0,
                    help="--serve per-request deadline / SLO budget")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--real-clock", action="store_true",
                    help="--serve in wall time with measured service "
                         "times (default: deterministic virtual clock)")
    ap.add_argument("--shortlist", default="off",
                    choices=("off", "on", "auto"),
                    help="2-stage shortlisted serving (DESIGN.md §11): "
                         "build+attach a label-partition index and "
                         "report recall@k vs exact in --bench")
    args = ap.parse_args()
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.serve:
        import json as _json

        rep = serve_runtime(cfg, batch=args.batch, k=args.k,
                            rate_qps=args.rate, burst_qps=args.burst_rate,
                            horizon_s=args.horizon,
                            slo_s=args.slo_ms / 1e3, seed=args.seed,
                            impl="xla" if args.smoke else "auto",
                            real_clock=args.real_clock,
                            verbose_plan=args.plan)
        print(_json.dumps(rep, indent=2, sort_keys=True))
        return
    if args.bench:
        stats = topk_bench(cfg, batch=args.batch, k=args.k,
                           queries=args.queries,
                           impl="xla" if args.smoke else "auto",
                           verbose_plan=args.plan,
                           shortlist=args.shortlist)
        print(f"topk bench: {stats['queries']} queries "
              f"(padded {stats['padded_rows']}) k={stats['k']} "
              f"path={stats['topk_path']} buckets={stats['bucket_sizes']}")
        print(f"  {stats['qps']:.1f} queries/s, "
              f"{stats['per_query_hbm_bytes'] / 2**20:.2f} MiB "
              "HBM traffic/query (W stream amortized over the bucket)")
        if stats["recall"] is not None:
            rec = " ".join(f"recall@{kk}={v:.4f}"
                           for kk, v in sorted(stats["recall"].items()))
            print(f"  shortlist C={stats['shortlist_c']} "
                  f"beam={stats['shortlist_beam']}: {rec} (vs exact)")
        return
    seqs, stats = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                        gen=args.gen, impl="xla" if args.smoke else "auto",
                        verbose_plan=args.plan)
    print("generated:", seqs[:2].tolist())
    print(f"prefill {stats['prefill_s']*1000:.0f} ms, "
          f"decode {stats['decode_tok_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
