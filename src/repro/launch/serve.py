"""Serving driver: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --batch 4 --prompt-len 16 --gen 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import head as RH
from repro.configs import get_config, get_smoke
from repro.launch import steps as St


def serve(cfg, *, batch: int, prompt_len: int, gen: int, impl: str = "auto",
          verbose_plan: bool = False):
    state = St.init_serve_state(jax.random.PRNGKey(0), cfg, batch,
                                max_len=prompt_len + gen, impl=impl)
    if verbose_plan:   # serving decisions (grid logits / top-k path)
        print(RH.get_head(St.make_head_cfg(cfg, impl),
                          batch=batch).plan.explain(), flush=True)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                         jnp.int32)
    fe_prompt = fe_step = None
    if cfg.frontend == "audio_frames":
        fe_prompt = jnp.asarray(rng.standard_normal(
            (batch, prompt_len, 512), np.float32), jnp.bfloat16)
        fe_step = jnp.zeros((batch, 1, 512), jnp.bfloat16)
    elif cfg.frontend == "vision":
        fe_prompt = fe_step = jnp.asarray(rng.standard_normal(
            (batch, cfg.n_frontend_tokens, 1280), np.float32), jnp.bfloat16)

    t0 = time.time()
    tok, state = St.serve_prefill(cfg, state, tokens, fe_prompt, impl=impl)
    prefill_s = time.time() - t0
    out = [np.asarray(tok)]
    t1 = time.time()
    for _ in range(gen - 1):
        tok, state = St.serve_decode(cfg, state, tok[:, None], fe_step,
                                     impl=impl)
        out.append(np.asarray(tok))
    decode_s = time.time() - t1
    seqs = np.stack(out, axis=1)
    return seqs, {"prefill_s": prefill_s,
                  "decode_tok_s": batch * (gen - 1) / max(decode_s, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--plan", action="store_true",
                    help="print the resolved HeadPlan before serving")
    args = ap.parse_args()
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    seqs, stats = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                        gen=args.gen, impl="xla" if args.smoke else "auto",
                        verbose_plan=args.plan)
    print("generated:", seqs[:2].tolist())
    print(f"prefill {stats['prefill_s']*1000:.0f} ms, "
          f"decode {stats['decode_tok_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
