"""Step functions: the paper's reordered computation flow as pure JAX.

``train_step`` (paper §4.2, Fig. 3 right):

    1. backbone forward             (under jax.vjp — no loss graph)
    2. ELMO head: chunked fwd / loss-skip grad / fused low-precision update
    3. backbone backward            (seeded with the head's input gradient —
                                     runs AFTER the head, when chunk buffers
                                     are free: the peak-memory reordering)
    4. Kahan-AdamW backbone update  (pure BF16, §4.1)

The head never appears in the autodiff graph (loss-skipping by
construction).  ``serve_prefill`` / ``serve_decode`` are the inference pair;
decode shapes lower ``serve_decode`` (one token against a full-length
cache), per the task spec.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import head as RH
from repro.head import HeadHparams
from repro.kernels import prng_utils as PR
from repro.numerics import telemetry as NT
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim.base import Optimizer


def make_head_cfg(cfg: ModelConfig, impl: str = "auto") -> RH.ELMOHeadConfig:
    return RH.head_config_for(cfg, impl=impl)


class TrainState(NamedTuple):
    backbone: T.Backbone
    opt_state: Any
    head: Any              # HeadState, or SparseHeadState when cfg.head_fan_in
    step: jax.Array


def _init_head_state(key: jax.Array, head_cfg: RH.ELMOHeadConfig):
    """Dense ``HeadState`` or (``fan_in`` configs, DESIGN.md §13) the
    fixed-fan-in ``SparseHeadState`` — same dispatch as ``ELMOHead.init``."""
    if head_cfg.fan_in:
        from repro.head import sparse as _sparse
        return _sparse.init_sparse_head(key, head_cfg)
    return RH.init_head(key, head_cfg)


def init_train_state(key: jax.Array, cfg: ModelConfig, optimizer: Optimizer,
                     impl: str = "auto") -> TrainState:
    kb, kh = jax.random.split(key)
    backbone = T.backbone_init(kb, cfg)
    head = _init_head_state(kh, make_head_cfg(cfg, impl))
    return TrainState(backbone, optimizer.init(backbone), head, jnp.int32(0))


def _head_step(head_cfg, head_state, x, targets, head_lr, head_wd, seed,
               step=None):
    """The ``ELMOHead`` facade dispatches single-device vs label-sharded
    from the ambient ``MeshContext`` and grid/fused/unfused/sparse from its
    ``HeadPlan`` — resolved once per (config, shape, mesh) by the memoized
    factory, never re-derived inside the traced step.

    ``step`` (when given, and the config schedules it) runs the sparse
    head's deterministic prune/regrow after the value update — a
    ``lax.cond`` on the traced step, so the jitted program is
    step-invariant."""
    head = RH.get_head(head_cfg, batch=x.shape[0],
                       target_slots=targets.shape[-1]
                       if targets.ndim == 2 else 1)
    out = head.train_step(head_state, x, targets,
                          HeadHparams(head_lr, head_wd, seed))
    if step is not None and head_cfg.fan_in and head_cfg.prune_every:
        new_state, xg, metrics = out
        new_state = head.maybe_prune_regrow(new_state, x, targets, step)
        out = (new_state, xg, metrics)
    return out


def _head_topk(head_cfg, head_state, x, k: int):
    head = RH.get_head(head_cfg, batch=x.shape[0])
    return head.topk(head_state, x, k)


def _head_inputs(cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    if cfg.pool == "first":        # XMC encoders: CLS pooling
        return hidden[:, 0, :]
    B, S, D = hidden.shape
    return hidden.reshape(B * S, D)


def _micro_seed(seed: jax.Array, micro_idx) -> jax.Array:
    """Per-microbatch PRNG stream for gradient accumulation.

    The scan index is mixed in so every microbatch draws *distinct*
    SR/DropConnect bits — a constant derivation (the historical
    ``mix32(seed + 1)``) replayed identical stochastic-rounding draws at
    every microbatch, correlating the quantization noise across the
    accumulation window."""
    return PR.mix32(seed + (jnp.uint32(micro_idx) + jnp.uint32(1))
                    * jnp.uint32(0x9E3779B9))


def _one_microbatch(cfg, head_cfg, backbone, head_state, tokens, targets,
                    frontend, head_lr, head_wd, seed, step=None):
    """fwd → chunked head (fwd/grad/update) → bwd. Returns head', grads,
    metrics — the paper's §4.2 ordering."""
    if cfg.head_loss == "softmax_ce":
        targets = targets.reshape(-1)      # (B·S,) next-token ids

    def fwd(bb):
        hidden = T.backbone_apply(bb, cfg, tokens, frontend)
        return _head_inputs(cfg, hidden)

    x, pullback = jax.vjp(fwd, backbone)
    head_new, x_grad, metrics = _head_step(
        head_cfg, head_state, x, targets, head_lr, head_wd, seed, step)
    (bb_grads,) = pullback(x_grad.astype(x.dtype))
    return head_new, bb_grads, metrics


def train_step(cfg: ModelConfig, optimizer: Optimizer, state: TrainState,
               batch: dict, head_lr: jax.Array, backbone_lr: jax.Array,
               head_wd: jax.Array = jnp.float32(1e-4),
               impl: str = "auto", seed_salt: int = 0
               ) -> Tuple[TrainState, dict]:
    head_cfg = make_head_cfg(cfg, impl)
    tokens = batch["tokens"]
    frontend = batch.get("frontend_embeds")
    targets = batch["targets"]
    # seed_salt (numerics-guard reseed rung, DESIGN.md §14) shifts the whole
    # step-derived SR/DropConnect stream; salt 0 is bit-identical to the
    # historical derivation, so an untripped run matches guard-off exactly
    seed = PR.mix32(state.step.astype(jnp.uint32)
                    + jnp.uint32(seed_salt) * jnp.uint32(0x632BE59B))
    n_micro = max(1, cfg.grad_accum)

    if n_micro == 1:
        head_new, bb_grads, metrics = _one_microbatch(
            cfg, head_cfg, state.backbone, state.head, tokens, targets,
            frontend, head_lr, head_wd, seed, step=state.step)
    else:
        # gradient accumulation: scan over microbatches; the head streams
        # its own fused updates per microbatch, backbone grads accumulate
        # in BF16 and the Kahan-AdamW update runs once
        B = tokens.shape[0]
        assert B % n_micro == 0, (B, n_micro)
        mb = B // n_micro

        def split(a):
            return (a.reshape(n_micro, mb, *a.shape[1:])
                    if a is not None else None)

        xs = (split(tokens), split(targets), split(frontend),
              jnp.arange(n_micro, dtype=jnp.uint32))

        def micro_body(carry, inp):
            head_state, gacc = carry
            tok, tgt, fe, mi = inp
            m_seed = _micro_seed(seed, mi)
            # prune/regrow cadence is defined on whole optimizer steps:
            # fire it on the accumulation-boundary microbatch only (−1 is
            # the controller's never-fires sentinel for the others)
            m_step = jnp.where(mi == jnp.uint32(n_micro - 1),
                               state.step, jnp.int32(-1))
            head_state, g, metrics = _one_microbatch(
                cfg, head_cfg, state.backbone, head_state, tok, tgt, fe,
                head_lr, head_wd, m_seed, step=m_step)
            gacc = jax.tree.map(
                lambda a, b: (a + b.astype(a.dtype)), gacc, g)
            ys = metrics["loss"]
            if head_cfg.guard:
                ys = (ys, metrics["telemetry"])
            return (head_state, gacc), ys

        gacc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                             state.backbone)
        (head_new, gacc), ys = jax.lax.scan(
            micro_body, (state.head, gacc0), xs)
        losses = ys[0] if head_cfg.guard else ys
        bb_grads = jax.tree.map(lambda g: g / n_micro, gacc)
        metrics = {"loss": losses.mean(),
                   "xgrad_norm": jnp.float32(0.0)}
        if head_cfg.guard:
            # per-microbatch vectors merge like chunks: counts add, the
            # comp max maxes (telemetry.combine, vectorized over the scan)
            teles = ys[1]
            slot = jnp.arange(teles.shape[1])
            metrics["telemetry"] = jnp.where(
                slot == NT.SLOTS["comp_max"], teles.max(0), teles.sum(0))

    bb_new, opt_new = optimizer.update(state.backbone, state.opt_state,
                                       bb_grads, state.step, backbone_lr)
    metrics = dict(metrics, step=state.step)
    return TrainState(bb_new, opt_new, head_new, state.step + 1), metrics


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


class ServeState(NamedTuple):
    backbone: T.Backbone
    head: Any              # HeadState, or SparseHeadState when cfg.head_fan_in
    caches: Any


def init_serve_state(key: jax.Array, cfg: ModelConfig, batch: int,
                     max_len: int, impl: str = "auto") -> ServeState:
    kb, kh = jax.random.split(key)
    backbone = T.backbone_init(kb, cfg)
    head = _init_head_state(kh, make_head_cfg(cfg, impl))
    return ServeState(backbone, head, T.init_caches(cfg, batch, max_len))


def serve_prefill(cfg: ModelConfig, state: ServeState, tokens: jax.Array,
                  frontend_embeds: Optional[jax.Array] = None,
                  impl: str = "auto") -> Tuple[jax.Array, ServeState]:
    """Process the prompt, fill caches, emit the first generated token."""
    head_cfg = make_head_cfg(cfg, impl)
    x, ctx = T._embed_inputs(state.backbone, cfg, tokens, frontend_embeds)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def period_body(carry, slices):
        x = carry
        param_slice, cache_slice = slices
        new_caches = []
        for bs, p, c in zip(cfg.pattern, param_slice, cache_slice):
            # prefill = train-style blockwise attention + cache population
            h = T.Ly.rmsnorm(p["norm1"], x, cfg.norm_eps)
            if bs.kind in ("attn", "hymba"):
                y_attn, kv = T.Attn.prefill_self_attention(p["attn"], cfg, h,
                                                           c["kv"])
                c = dict(c, kv=kv)
            if bs.kind == "attn":
                y = y_attn
            elif bs.kind == "hymba":
                y_ssm = T.Ssm.ssm_apply(p["ssm"], cfg, h)
                y = 0.5 * (T.Ly.rmsnorm(p["norm_attn_out"], y_attn,
                                        cfg.norm_eps)
                           + T.Ly.rmsnorm(p["norm_ssm_out"], y_ssm,
                                          cfg.norm_eps))
                # populate the end-of-prompt SSM state with a stateful pass
                _, ssm_c = _ssm_prefill_state(p["ssm"], cfg, h)
                c = dict(c, ssm=ssm_c)
            elif bs.kind == "mamba":
                y = T.Ssm.ssm_apply(p["ssm"], cfg, h)
                _, ssm_c = _ssm_prefill_state(p["ssm"], cfg, h)
                c = dict(c, ssm=ssm_c)
            elif bs.kind == "mlstm":
                y = T.Xl.mlstm_apply(p["mlstm"], cfg, h)
                c = dict(c, mlstm=_mlstm_prefill_state(p["mlstm"], cfg, h))
            elif bs.kind == "slstm":
                y, c_sl = _slstm_prefill(p["slstm"], cfg, h)
                c = dict(c, slstm=c_sl)
            else:
                raise ValueError(bs.kind)
            x = x + y
            if bs.cross_attn:
                x = x + T.Attn.cross_attention(
                    p["cross"], cfg,
                    T.Ly.rmsnorm(p["norm_cross"], x, cfg.norm_eps), ctx)
            x = x + T._ffn_part(p, cfg, bs, x)
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(period_body, x,
                                 (state.backbone.periods, state.caches))
    hidden = T.Ly.rmsnorm(state.backbone.final_norm, x, cfg.norm_eps)
    _, next_tok = _head_topk(head_cfg, state.head, hidden[:, -1, :], k=1)
    return next_tok[:, 0], ServeState(state.backbone, state.head, new_caches)


def _ssm_prefill_state(p, cfg, h):
    """Run the SSM over the prompt, returning the end-of-prompt state."""
    B, S, _ = h.shape
    xz = T.Ly.dense(p["in_proj"], h)
    x_in, _ = jnp.split(xz, 2, axis=-1)
    x_conv, conv_state = T.Ssm._causal_conv(p["conv_w"], p["conv_b"], x_in)
    x_c = jax.nn.silu(x_conv.astype(jnp.float32)).astype(h.dtype)
    h0 = jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32)
    _, h_last = T.Ssm._ssm_inner(p, cfg, x_c, h0, chunk=128)
    return None, T.Ssm.SSMCache(h_last, conv_state.astype(jnp.bfloat16))


def _mlstm_prefill_state(p, cfg, h):
    B, S, _ = h.shape
    H, dh = T.Xl._heads(cfg)
    k = T.Ly.dense(p["w_k"], h).reshape(B, S, H, dh)
    v = T.Ly.dense(p["w_v"], h).reshape(B, S, H, dh)
    q = T.Ly.dense(p["w_q"], h).reshape(B, S, H, dh)
    logf, logi = T.Xl._mlstm_gates(p, h)
    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    Wc = min(64, S)
    pad = (-S) % Wc
    if pad:
        q, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for a in (q, k, v))
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)),
                       constant_values=-50.0)
    nc = (S + pad) // Wc
    xs = tuple(a.reshape(B, nc, Wc, *a.shape[2:]).swapaxes(0, 1)
               for a in (q, k, v, logf, logi))

    def body(carry, inp):
        C, n = carry
        _, C1, n1 = T.Xl._mlstm_chunk(*inp, C, n, 1.0 / (dh ** 0.5))
        return (C1, n1), None

    (C1, n1), _ = jax.lax.scan(body, (C0, n0), xs)
    return T.Xl.MLSTMCache(C1, n1)


def _slstm_prefill(p, cfg, h):
    B, S, _ = h.shape

    def body(cache, xt):
        cache = T.Xl._slstm_step(p, cfg, xt, cache)
        return cache, cache.h

    cache0 = T.Xl.init_slstm_cache(cfg, B)
    cache, hs = jax.lax.scan(body, cache0, h.swapaxes(0, 1))
    y = T.Ly.dense(p["w_o"],
                   hs.swapaxes(0, 1).reshape(B, S, -1).astype(h.dtype))
    return y, cache


def serve_decode(cfg: ModelConfig, state: ServeState, token: jax.Array,
                 frontend_embeds: Optional[jax.Array] = None,
                 impl: str = "auto") -> Tuple[jax.Array, ServeState]:
    """One token in → one token out (greedy), caches advanced."""
    head_cfg = make_head_cfg(cfg, impl)
    hidden, new_caches = T.backbone_decode_step(state.backbone, cfg, token,
                                                state.caches, frontend_embeds)
    _, next_tok = _head_topk(head_cfg, state.head, hidden[:, 0, :], k=1)
    return next_tok[:, 0], ServeState(state.backbone, state.head, new_caches)
