"""Launch layer: mesh construction, step functions, dry-run, train/serve
drivers."""
