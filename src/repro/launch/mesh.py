"""Production mesh construction (task spec: a FUNCTION, never module-level —
importing this module must not touch jax device state)."""
from __future__ import annotations

import jax

from repro.dist.compat import make_mesh
from repro.dist.meshctx import MeshContext


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_context(*, multi_pod: bool = False) -> MeshContext:
    mesh = make_production_mesh(multi_pod=multi_pod)
    return MeshContext(mesh=mesh, data_axes=("data",), model_axis="model",
                       pod_axis="pod" if multi_pod else None)


def make_host_mesh(n_data: int = 1, n_model: int = 1) -> MeshContext:
    """Small mesh over host devices (tests with forced device count)."""
    mesh = make_mesh((n_data, n_model), ("data", "model"))
    return MeshContext(mesh=mesh, data_axes=("data",), model_axis="model")
