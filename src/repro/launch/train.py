"""Training driver: data pipeline + ELMO step + checkpointing + fault
tolerance, under any mesh.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --smoke --steps 20 --ckpt-dir /tmp/ckpt

``--smoke`` uses the reduced config (CPU-runnable end to end); without it
the full config is used (requires a real fleet).  The loop implements the
production contract (DESIGN.md §10):

* deterministic **next**-batch data cursor and the head's
  ``HeadPlan.checkpoint_meta()`` in every checkpoint manifest;
* async checksummed saves whose background failures surface in the loop;
* restore-before-shard: an elastic restart restores the last committed
  (intact) checkpoint and *then* places the head per ``dist.sharding`` —
  a mesh-shape change across the restart is just a different placement;
* per-step heartbeats; a stale peer raises ``HostFailure`` out of the
  loop, and ``run_elastic`` re-plans the fleet with ``ElasticController``
  and re-enters training from the checkpoint;
* transient data-pipeline errors absorbed by ``fault.retry`` around the
  batch fetch (the iterator is only advanced on success);
* peer step-times fed to ``StragglerMonitor`` from heartbeat records.

A SIGKILL at ANY point resumes bit-identically: state, data cursor and the
step-derived SR/DropConnect seeds are all functions of the committed step.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

import contextlib

from repro import head as RH
from repro.checkpoint import CheckpointManager, restore_checkpoint
from repro.checkpoint.ckpt import latest_committed
from repro.configs import get_config, get_smoke
from repro.data import DataCursor, lm_batches, xmc_batches
from repro.dist import meshctx, sharding
from repro.fault import (ElasticController, Heartbeat, HostFailure,
                         StragglerMonitor, retry)
from repro.launch import steps as St
from repro.launch.mesh import make_host_mesh
from repro.numerics import recovery as NR
from repro.numerics.monitor import NumericsMonitor
from repro.optim import kahan_adamw, linear_warmup_constant


def make_batches(cfg, global_batch: int, seq: int, cursor: DataCursor,
                 host_id: int = 0, n_hosts: int = 1):
    if cfg.head_labels:
        return xmc_batches(cfg.vocab, cfg.head_labels, global_batch, seq,
                           cfg.max_labels_per_example, cursor, host_id,
                           n_hosts)
    return lm_batches(cfg.vocab, global_batch, seq, cursor, host_id, n_hosts)


def _shard_head(state: St.TrainState, cfg, ctx) -> St.TrainState:
    """Place the head per ``dist.sharding.head_specs`` (label rows over the
    model axis).  Runs AFTER checkpoint restore, so an elastic restart onto
    a different mesh shape is just this placement applied to the restored
    full-logical leaves."""
    specs = (sharding.sparse_head_specs(cfg, ctx.model_size)
             if getattr(cfg, "head_fan_in", 0)
             else sharding.head_specs(cfg, ctx.model_size))
    mesh = ctx.mesh

    def put(leaf, spec):
        if leaf is None:
            return None
        spec = sharding.sanitize_spec(leaf.shape, spec, mesh)
        return jax.device_put(leaf, jax.sharding.NamedSharding(mesh, spec))

    head = jax.tree.map(put, state.head, specs,
                        is_leaf=lambda x: x is None or hasattr(x, "shape"))
    return state._replace(head=head)


def _check_restore_meta(extra: dict, cfg, ladder=None) -> None:
    """Cross-check the manifest's head-plan metadata against this run's
    config: a weight-dtype change cannot be resumed bit-identically (the
    mesh MAY change — leaves are full-logical; see HeadPlan.checkpoint_meta).

    Exception: the numerics guard's ``escalate_precision`` rung (§14).  When
    the persisted ladder says this run's dtype IS the escalated one, a
    lower-precision checkpoint is the expected rollback source — restore
    upcasts it exactly (e4m3→bf16 is value-preserving), the re-typed
    ``convert_head`` semantics applied in place."""
    meta = extra.get("head_plan")
    if not meta:
        return
    want = getattr(cfg, "head_weight_dtype", None)
    got = meta.get("weight_dtype")
    if want is not None and got is not None and got != want:
        if ladder is not None and ladder.weight_dtype == want:
            print(f"numerics guard: restoring {got} checkpoint into "
                  f"escalated {want} head (exact upcast)", flush=True)
            return
        raise RuntimeError(
            f"checkpoint was written with head weight_dtype={got!r} but this "
            f"run uses {want!r}; convert explicitly (repro.head.convert) "
            "instead of resuming")


def train(cfg, *, steps: int, global_batch: int, seq: int, ckpt_dir: str,
          head_lr: float = 0.05, backbone_lr: float = 2e-5,
          ckpt_every: int = 50, impl: str = "auto", log_every: int = 1,
          host_id: int = 0, n_hosts: int = 1, n_data: int = 1,
          n_model: int = 1, hb_timeout: float = 60.0, data_retries: int = 3,
          on_step=None, guard: bool = False, monitor_kw=None, inject=None,
          head_lr_sched=None):
    """``n_model`` > 1 runs the label-sharded head (vocab parallelism over a
    host mesh — DESIGN.md §6); ``n_data`` shards the batch on top.
    ``on_step(i)`` is an observation hook (fault injection, tests);
    ``inject(i, state) -> state`` mutates state *before* step ``i`` (numeric
    fault injection).  ``guard`` arms the numerics monitor (DESIGN.md §14):
    per-step kernel telemetry feeds a ``NumericsMonitor`` and a trip raises
    ``NumericsTrip`` out of the loop for ``run_guarded`` to handle."""
    ctx = (make_host_mesh(n_data, n_model)
           if n_data * n_model > 1 else None)
    with (meshctx.use(ctx) if ctx is not None else contextlib.nullcontext()):
        return _train_inner(cfg, ctx, steps=steps, global_batch=global_batch,
                            seq=seq, ckpt_dir=ckpt_dir, head_lr=head_lr,
                            backbone_lr=backbone_lr, ckpt_every=ckpt_every,
                            impl=impl, log_every=log_every, host_id=host_id,
                            n_hosts=n_hosts, hb_timeout=hb_timeout,
                            data_retries=data_retries, on_step=on_step,
                            guard=guard, monitor_kw=monitor_kw,
                            inject=inject, head_lr_sched=head_lr_sched)


def _train_inner(cfg, ctx, *, steps: int, global_batch: int, seq: int,
                 ckpt_dir: str, head_lr: float, backbone_lr: float,
                 ckpt_every: int, impl: str, log_every: int,
                 host_id: int, n_hosts: int, hb_timeout: float,
                 data_retries: int, on_step, guard: bool = False,
                 monitor_kw=None, inject=None, head_lr_sched=None):
    opt = kahan_adamw()
    sched = linear_warmup_constant(backbone_lr, warmup_steps=100)

    guard = guard or getattr(cfg, "head_guard", False)
    ladder = None
    seed_salt = 0
    if guard:
        # the persisted escalation ladder is the recovery manifest: every
        # knob below is a pure function of it, so a SIGKILL anywhere in the
        # recovery sequence resumes bit-identically (DESIGN.md §14)
        ladder = NR.load_ladder(ckpt_dir) if ckpt_dir else NR.LadderState()
        if not getattr(cfg, "head_guard", False):
            cfg = dataclasses.replace(cfg, head_guard=True)
        if (ladder.weight_dtype
                and ladder.weight_dtype != cfg.head_weight_dtype):
            cfg = dataclasses.replace(cfg,
                                      head_weight_dtype=ladder.weight_dtype)
        head_lr = head_lr * ladder.lr_scale
        seed_salt = ladder.seed_salt
        if ladder.trips:
            print(f"numerics guard: resuming under {ladder.describe()}",
                  flush=True)

    state = St.init_train_state(jax.random.PRNGKey(0), cfg, opt, impl=impl)
    # resolve + log the head's execution plan once, up front: path, blocks,
    # byte estimates and any fallback are part of the run record.  The head
    # sees one MICRObatch per step (grad accumulation scans), so the plan
    # must be resolved at that size or the logged decision could differ
    # from the executed one.
    hcfg = St.make_head_cfg(cfg, impl)
    mb = global_batch // max(1, cfg.grad_accum)
    head = RH.get_head(hcfg,
                       batch=(mb if cfg.pool == "first" else mb * seq),
                       target_slots=RH.default_target_slots(cfg))
    print(head.plan.explain(), flush=True)
    cursor = DataCursor(seed=1234, step=0)
    start = 0
    if ckpt_dir and latest_committed(ckpt_dir):
        # restore BEFORE sharding: leaves come back full-logical; the
        # placement below reshards them onto whatever mesh this (possibly
        # shrunken) incarnation runs — corrupt/torn checkpoints are demoted
        # inside restore_checkpoint and the previous committed step is used
        state, start, extra = restore_checkpoint(ckpt_dir, state)
        _check_restore_meta(extra, cfg, ladder)
        cursor = DataCursor.from_state(extra.get("cursor", cursor.state()))
        print(f"restored step {start} (data cursor {cursor})", flush=True)
    if ctx is not None and ctx.model_size > 1:
        state = _shard_head(state, cfg, ctx)

    nmon = None
    if guard:
        n_micro = max(1, cfg.grad_accum)
        upd = hcfg.padded_labels * (hcfg.fan_in or hcfg.d_model)
        nmon = NumericsMonitor(update_elems=upd * n_micro,
                               sat_frac=hcfg.guard_sat_frac,
                               **(monitor_kw or {}))

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    hb = (Heartbeat(os.path.join(ckpt_dir, "hb"), host_id,
                    timeout_s=hb_timeout) if ckpt_dir else None)
    monitor = StragglerMonitor()
    ckpt_meta = {"head_plan": dict(head.plan.checkpoint_meta(),
                                   weight_dtype=hcfg.weight_dtype),
                 "mesh": {"n_hosts": n_hosts,
                          "shape": None if ctx is None
                          else dict(ctx.mesh.shape)}}

    @jax.jit
    def jstep(state, tokens, targets, frontend, lr_b, lr_h):
        batch = {"tokens": tokens, "targets": targets}
        if frontend is not None:
            batch["frontend_embeds"] = frontend
        return St.train_step(cfg, opt, state, batch,
                             head_lr=lr_h,
                             backbone_lr=lr_b, impl=impl,
                             seed_salt=seed_salt)

    batches = make_batches(cfg, global_batch, seq, cursor, host_id, n_hosts)
    losses = []
    peer_beats = {}
    for i in range(start, steps):
        t0 = time.time()
        # transient pipeline errors (flaky storage, preempted reader) are
        # retried; the iterator only advances on success so no batch is
        # skipped or duplicated
        batch = retry(lambda: next(batches), attempts=data_retries,
                      base_delay_s=0.01)
        frontend = None
        if cfg.frontend == "audio_frames":
            frontend = jnp.asarray(
                np.random.default_rng(i).standard_normal(
                    (batch["tokens"].shape[0], seq, 512), np.float32),
                jnp.bfloat16)
        elif cfg.frontend == "vision":
            frontend = jnp.asarray(
                np.random.default_rng(i).standard_normal(
                    (batch["tokens"].shape[0], cfg.n_frontend_tokens, 1280),
                    np.float32), jnp.bfloat16)
        if inject is not None:
            state = inject(i, state)
        hl = head_lr
        if head_lr_sched is not None:     # the schedule yields the BASE lr;
            hl = float(head_lr_sched(i))  # the ladder's backoff still applies
            if ladder is not None:
                hl *= ladder.lr_scale
        state, metrics = jstep(state, jnp.asarray(batch["tokens"]),
                               jnp.asarray(batch["targets"]), frontend,
                               sched(jnp.int32(i)), jnp.float32(hl))
        loss = float(metrics["loss"])
        losses.append(loss)
        if nmon is not None:
            tele = metrics.get("telemetry")
            trip = nmon.observe(
                i, loss, None if tele is None else np.asarray(tele, np.float64))
            if trip is not None:
                if mgr:
                    mgr.wait()      # land pre-trip saves; nothing after this
                #                     step is ever committed
                print(f"NUMERICS TRIP at step {i}: {trip.kind} "
                      f"({trip.detail or trip.value})", flush=True)
                raise NR.NumericsTrip(trip, losses)
        dt = time.time() - t0
        monitor.record(host_id, dt)
        if hb:
            hb.beat(i)
        if on_step is not None:
            on_step(i)
        if hb is not None and n_hosts > 1:
            # feed peer step times (from their heartbeat records) to the
            # straggler monitor, then check liveness: a stale peer stalls
            # the whole SPMD program, so bail to the elastic driver
            for h, rec in hb.records(n_hosts).items():
                prev = peer_beats.get(h)
                if prev and rec["step"] > prev["step"]:
                    monitor.record(h, (rec["t"] - prev["t"])
                                   / (rec["step"] - prev["step"]))
                peer_beats[h] = rec
            lagging = [h for h in monitor.stragglers() if h != host_id]
            if lagging and i % log_every == 0:
                print(f"step {i:5d}  stragglers {lagging} "
                      "(candidates for preemptive replacement)", flush=True)
            alive = hb.alive_hosts(n_hosts)
            if len(alive) < n_hosts:
                dead = sorted(set(range(n_hosts)) - set(alive))
                if mgr:
                    mgr.wait()      # land the in-flight save before bailing
                raise HostFailure(dead=dead, alive=alive, step=i,
                                  losses=losses)
        if i % log_every == 0:
            print(f"step {i:5d}  loss {loss:.4f}  {dt*1000:.0f} ms",
                  flush=True)
        if mgr and (i + 1) % ckpt_every == 0:
            # the NEXT batch's cursor: restore must replay the first
            # unconsumed batch, not re-train the one this step just saw
            mgr.save_async(i + 1, state,
                           extra=dict(ckpt_meta,
                                      cursor=batch["next_cursor"]))
    if mgr:
        mgr.wait()
    return state, losses


def run_elastic(cfg, *, steps: int, global_batch: int, seq: int,
                ckpt_dir: str, n_hosts: int, controller=None,
                max_restarts: int = 4, **kw):
    """The supervision path: train under heartbeat watch; on a dead host,
    plan the shrunken fleet with ``ElasticController``, clear the stale
    heartbeat fleet, and re-enter ``train`` — which restores the last
    committed checkpoint (with its data cursor) and continues.

    Returns ``(state, losses, restarts)`` where ``losses`` concatenates
    every incarnation's steps (failed attempts contribute the steps they
    completed before the failure was detected)."""
    controller = controller or ElasticController(n_hosts=n_hosts,
                                                 min_hosts=1)
    hosts = list(range(n_hosts))
    all_losses: list = []
    restarts = 0
    while True:
        try:
            state, losses = train(cfg, steps=steps,
                                  global_batch=global_batch, seq=seq,
                                  ckpt_dir=ckpt_dir, host_id=0,
                                  n_hosts=len(hosts), **kw)
            return state, all_losses + losses, restarts
        except HostFailure as e:
            plan = controller.plan_after_failure(e.alive)
            print(f"host failure at step {e.step}: dead={e.dead} → {plan}",
                  flush=True)
            if plan["action"] != "restart" or restarts >= max_restarts:
                raise
            # steps the failed incarnation completed are real: they are in
            # the committed checkpoint the next incarnation resumes from
            ckpt_step = 0
            last = latest_committed(ckpt_dir)
            if last is not None:
                ckpt_step = int(os.path.basename(last)[len("ckpt_"):])
            all_losses += e.losses[:max(0, ckpt_step - (e.step + 1
                                                        - len(e.losses)))]
            hosts = plan["hosts"]
            # the next incarnation starts a fresh heartbeat fleet (dead
            # hosts' stale files must not instantly re-fail it)
            shutil.rmtree(os.path.join(ckpt_dir, "hb"), ignore_errors=True)
            restarts += 1


def run_guarded(cfg, *, steps: int, global_batch: int, seq: int,
                ckpt_dir: str, max_recoveries: int = 4, **kw):
    """The numerics-guard supervision path (DESIGN.md §14): train with the
    monitor armed; on a ``NumericsTrip``,

    1. escalate + persist the ladder (``guard.json``) — FIRST, so a SIGKILL
       between here and the restart replays the same recovery;
    2. quarantine the newest committed checkpoint (the suspect — its state
       is at or just behind the trip) via §10's CORRUPT demotion;
    3. re-enter ``train`` — which restores last-good and applies the rung
       (fresh SR salt → LR backoff → bf16 escalation).

    Each additional trip at the same rung demotes one more checkpoint, so
    the rollback horizon recedes deterministically until the run clears the
    bad region.  Returns ``(state, losses, recoveries)``; ``losses``
    concatenates every incarnation's real (committed) steps."""
    assert ckpt_dir, "run_guarded needs a checkpoint dir to roll back to"
    base_dtype = getattr(cfg, "head_weight_dtype", "e4m3")
    all_losses: list = []
    recoveries = 0
    while True:
        try:
            state, losses = train(cfg, steps=steps,
                                  global_batch=global_batch, seq=seq,
                                  ckpt_dir=ckpt_dir, guard=True, **kw)
            return state, all_losses + losses, recoveries
        except NR.NumericsTrip as e:
            # injected poison fires once: the recovered incarnation is clean
            # (a genuine re-occurrence escalates through the ladder instead)
            kw["inject"] = None
            kw["head_lr_sched"] = None
            ladder = NR.load_ladder(ckpt_dir).escalate(
                e.reason.as_dict(), base_dtype=base_dtype)
            NR.save_ladder(ckpt_dir, ladder)     # BEFORE quarantine: the
            #   ladder is the recovery manifest — kill-safe ordering
            last = latest_committed(ckpt_dir)
            demoted = []
            if last is not None:
                horizon = int(os.path.basename(last)[len("ckpt_"):])
                demoted = NR.quarantine(ckpt_dir, horizon)
            print(f"numerics recovery #{recoveries + 1}: {e} → "
                  f"{ladder.describe()}; quarantined "
                  f"{[os.path.basename(p) for p in demoted]}", flush=True)
            if recoveries >= max_recoveries:
                raise
            # steps up to the checkpoint the next incarnation resumes from
            # are real; everything after it is rolled back
            ckpt_step = 0
            last = latest_committed(ckpt_dir)
            if last is not None:
                ckpt_step = int(os.path.basename(last)[len("ckpt_"):])
            all_losses += e.losses[:max(
                0, ckpt_step - (e.reason.step + 1 - len(e.losses)))]
            recoveries += 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--head-lr", type=float, default=0.05)
    ap.add_argument("--backbone-lr", type=float, default=2e-5)
    ap.add_argument("--n-data", type=int, default=1,
                    help="data-parallel mesh axis size")
    ap.add_argument("--n-model", type=int, default=1,
                    help="model mesh axis size (label-sharded head)")
    ap.add_argument("--vocab", type=int, default=None,
                    help="vocab override for --smoke (smaller = faster)")
    ap.add_argument("--head-labels", type=int, default=None,
                    help="label-count override for --smoke (XMC archs keep "
                         "their full label space under reduced(); smaller = "
                         "faster)")
    ap.add_argument("--head-fan-in", type=int, default=None,
                    help="fixed-fan-in sparse head override for --smoke "
                         "(DESIGN.md §13; 0 = dense)")
    ap.add_argument("--head-prune-every", type=int, default=None,
                    help="sparse prune/regrow cadence override for --smoke")
    ap.add_argument("--losses-out", default="",
                    help="write {start, losses} json (fault-injection "
                         "harness compares trajectories across kills)")
    ap.add_argument("--guard", action="store_true",
                    help="arm the numerics guard: kernel telemetry + "
                         "divergence monitor + rollback-and-escalate "
                         "recovery (DESIGN.md §14)")
    ap.add_argument("--guard-max-recoveries", type=int, default=4)
    ap.add_argument("--guard-warmup", type=int, default=8,
                    help="EWMA warm-up steps before loss-spike trips arm")
    ap.add_argument("--inject-nan-step", type=int, default=None,
                    help="NaN-poison one head weight before step N "
                         "(numerics-guard e2e harness)")
    ap.add_argument("--inject-sat-step", type=int, default=None,
                    help="force-saturate the head update stream before "
                         "step N (needs a Kahan head)")
    ap.add_argument("--inject-lr-spike-step", type=int, default=None,
                    help="spike the head LR for exactly step N")
    ap.add_argument("--inject-lr-spike-factor", type=float, default=64.0)
    args = ap.parse_args()

    overrides = {"vocab": args.vocab} if args.vocab else {}
    if args.head_labels is not None:
        overrides["head_labels"] = args.head_labels
    if args.head_fan_in is not None:
        overrides["head_fan_in"] = args.head_fan_in
    if args.head_prune_every is not None:
        overrides["head_prune_every"] = args.head_prune_every
    cfg = (get_smoke(args.arch, **overrides) if args.smoke
           else get_config(args.arch))

    from repro.fault import inject as FI
    hook = None
    if args.inject_nan_step is not None:
        hook = FI.at_step(args.inject_nan_step, FI.nan_poison_head)
    elif args.inject_sat_step is not None:
        hook = FI.at_step(args.inject_sat_step, FI.saturate_head)
    lr_sched = None
    if args.inject_lr_spike_step is not None:
        lr_sched = FI.lr_spike(args.head_lr, step=args.inject_lr_spike_step,
                               factor=args.inject_lr_spike_factor)
    if ((hook or lr_sched) and args.guard and args.ckpt_dir
            and NR.load_ladder(args.ckpt_dir).trips):
        # a restarted (e.g. SIGKILLed-mid-recovery) guarded run has already
        # taken this poison: recovery must replay clean, not re-trip
        hook = lr_sched = None

    common = dict(steps=args.steps, global_batch=args.global_batch,
                  seq=args.seq, ckpt_dir=args.ckpt_dir,
                  ckpt_every=args.ckpt_every, head_lr=args.head_lr,
                  backbone_lr=args.backbone_lr,
                  impl="xla" if args.smoke else "auto",
                  inject=hook, head_lr_sched=lr_sched)
    if args.guard:
        _, losses, recoveries = run_guarded(
            cfg, max_recoveries=args.guard_max_recoveries,
            monitor_kw={"warmup": args.guard_warmup}, **common)
        print(f"numerics guard: {recoveries} recovery(ies); final ladder: "
              f"{NR.load_ladder(args.ckpt_dir).describe()}", flush=True)
    else:
        _, losses = train(cfg, n_data=args.n_data, n_model=args.n_model,
                          **common)
    if args.losses_out:
        with open(args.losses_out, "w") as f:
            json.dump({"start": args.steps - len(losses),
                       "losses": losses}, f)
    if losses:
        print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")
    else:       # resumed past the last step: nothing left to train
        print("final loss n/a (restored checkpoint already at --steps)")


if __name__ == "__main__":
    main()
