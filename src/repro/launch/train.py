"""Training driver: data pipeline + ELMO step + checkpointing + fault
tolerance, under any mesh.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --smoke --steps 20 --ckpt-dir /tmp/ckpt

``--smoke`` uses the reduced config (CPU-runnable end to end); without it
the full config is used (requires a real fleet).  The loop demonstrates the
production contract: deterministic data cursor in every checkpoint, async
saves, heartbeat + straggler hooks, elastic restore on restart.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import contextlib

from repro import head as RH
from repro.checkpoint import CheckpointManager, restore_checkpoint
from repro.checkpoint.ckpt import latest_committed
from repro.configs import get_config, get_smoke
from repro.data import DataCursor, lm_batches, xmc_batches
from repro.dist import meshctx, sharding
from repro.fault import Heartbeat, StragglerMonitor
from repro.launch import steps as St
from repro.launch.mesh import make_host_mesh
from repro.optim import kahan_adamw, linear_warmup_constant


def make_batches(cfg, global_batch: int, seq: int, cursor: DataCursor,
                 host_id: int = 0, n_hosts: int = 1):
    if cfg.head_labels:
        return xmc_batches(cfg.vocab, cfg.head_labels, global_batch, seq,
                           cfg.max_labels_per_example, cursor, host_id,
                           n_hosts)
    return lm_batches(cfg.vocab, global_batch, seq, cursor, host_id, n_hosts)


def _shard_head(state: St.TrainState, cfg, ctx) -> St.TrainState:
    """Place the head per ``dist.sharding.head_specs`` (label rows over the
    model axis) so the sharded step starts from a vocab-parallel layout
    instead of resharding replicated weights every step."""
    specs = sharding.head_specs(cfg, ctx.model_size)
    mesh = ctx.mesh

    def put(leaf, spec):
        if leaf is None:
            return None
        spec = sharding.sanitize_spec(leaf.shape, spec, mesh)
        return jax.device_put(leaf, jax.sharding.NamedSharding(mesh, spec))

    head = jax.tree.map(put, state.head, specs,
                        is_leaf=lambda x: x is None or hasattr(x, "shape"))
    return state._replace(head=head)


def train(cfg, *, steps: int, global_batch: int, seq: int, ckpt_dir: str,
          head_lr: float = 0.05, backbone_lr: float = 2e-5,
          ckpt_every: int = 50, impl: str = "auto", log_every: int = 1,
          host_id: int = 0, n_hosts: int = 1, n_data: int = 1,
          n_model: int = 1):
    """``n_model`` > 1 runs the label-sharded head (vocab parallelism over a
    host mesh — DESIGN.md §6); ``n_data`` shards the batch on top."""
    ctx = (make_host_mesh(n_data, n_model)
           if n_data * n_model > 1 else None)
    with (meshctx.use(ctx) if ctx is not None else contextlib.nullcontext()):
        return _train_inner(cfg, ctx, steps=steps, global_batch=global_batch,
                            seq=seq, ckpt_dir=ckpt_dir, head_lr=head_lr,
                            backbone_lr=backbone_lr, ckpt_every=ckpt_every,
                            impl=impl, log_every=log_every, host_id=host_id,
                            n_hosts=n_hosts)


def _train_inner(cfg, ctx, *, steps: int, global_batch: int, seq: int,
                 ckpt_dir: str, head_lr: float, backbone_lr: float,
                 ckpt_every: int, impl: str, log_every: int,
                 host_id: int, n_hosts: int):
    opt = kahan_adamw()
    sched = linear_warmup_constant(backbone_lr, warmup_steps=100)

    state = St.init_train_state(jax.random.PRNGKey(0), cfg, opt, impl=impl)
    # resolve + log the head's execution plan once, up front: path, blocks,
    # byte estimates and any fallback are part of the run record.  The head
    # sees one MICRObatch per step (grad accumulation scans), so the plan
    # must be resolved at that size or the logged decision could differ
    # from the executed one.
    hcfg = St.make_head_cfg(cfg, impl)
    mb = global_batch // max(1, cfg.grad_accum)
    head = RH.get_head(hcfg,
                       batch=(mb if cfg.pool == "first" else mb * seq),
                       target_slots=RH.default_target_slots(cfg))
    print(head.plan.explain(), flush=True)
    if ctx is not None and ctx.model_size > 1:
        state = _shard_head(state, cfg, ctx)
    cursor = DataCursor(seed=1234, step=0)
    start = 0
    if ckpt_dir and latest_committed(ckpt_dir):
        state, start, extra = restore_checkpoint(ckpt_dir, state)
        cursor = DataCursor.from_state(extra.get("cursor", cursor.state()))
        print(f"restored step {start} (data cursor {cursor})", flush=True)

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    hb = Heartbeat(ckpt_dir + "/hb", host_id) if ckpt_dir else None
    monitor = StragglerMonitor()

    @jax.jit
    def jstep(state, tokens, targets, frontend, lr_b):
        batch = {"tokens": tokens, "targets": targets}
        if frontend is not None:
            batch["frontend_embeds"] = frontend
        return St.train_step(cfg, opt, state, batch,
                             head_lr=jnp.float32(head_lr),
                             backbone_lr=lr_b, impl=impl)

    batches = make_batches(cfg, global_batch, seq, cursor, host_id, n_hosts)
    losses = []
    for i, batch in zip(range(start, steps), batches):
        t0 = time.time()
        frontend = None
        if cfg.frontend == "audio_frames":
            frontend = jnp.asarray(
                np.random.default_rng(i).standard_normal(
                    (batch["tokens"].shape[0], seq, 512), np.float32),
                jnp.bfloat16)
        elif cfg.frontend == "vision":
            frontend = jnp.asarray(
                np.random.default_rng(i).standard_normal(
                    (batch["tokens"].shape[0], cfg.n_frontend_tokens, 1280),
                    np.float32), jnp.bfloat16)
        state, metrics = jstep(state, jnp.asarray(batch["tokens"]),
                               jnp.asarray(batch["targets"]), frontend,
                               sched(jnp.int32(i)))
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t0
        monitor.record(host_id, dt)
        if hb:
            hb.beat(i)
        if i % log_every == 0:
            print(f"step {i:5d}  loss {loss:.4f}  {dt*1000:.0f} ms",
                  flush=True)
        if mgr and (i + 1) % ckpt_every == 0:
            mgr.save_async(i + 1, state,
                           extra={"cursor": batch["cursor"]})
    if mgr:
        mgr.wait()
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--head-lr", type=float, default=0.05)
    ap.add_argument("--backbone-lr", type=float, default=2e-5)
    ap.add_argument("--n-data", type=int, default=1,
                    help="data-parallel mesh axis size")
    ap.add_argument("--n-model", type=int, default=1,
                    help="model mesh axis size (label-sharded head)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    _, losses = train(cfg, steps=args.steps, global_batch=args.global_batch,
                      seq=args.seq, ckpt_dir=args.ckpt_dir,
                      head_lr=args.head_lr, backbone_lr=args.backbone_lr,
                      impl="xla" if args.smoke else "auto",
                      n_data=args.n_data, n_model=args.n_model)
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
