"""Admission control: shed at the door, not in the queue.

Under overload the worst failure mode is accepting work that cannot
meet its deadline — it clogs the queue, starves feasible requests, and
turns one slow burst into a collapse.  Admission therefore rejects at
submit time, with an explicit ``REJECTED`` terminal outcome and a
reason, on three gates (checked in order):

* ``queue_full``        — the bounded queue is at capacity (backpressure
                          floor: memory can never grow with load).
* ``predicted_late``    — the predicted wait (in-flight remainder +
                          queue drain at current service estimates) plus
                          this request's own service already exceeds its
                          deadline: admitting it would only manufacture
                          a TIMED_OUT.
* ``tenant_throttled``  — the tenant's token bucket is empty (per-tenant
                          rate × burst fairness; a hot tenant cannot
                          starve the rest).  Checked last so only
                          otherwise-admittable requests spend tokens.

Per-tenant ``max_k`` is applied here too (the request's k is clamped,
not rejected), so a tenant's serving cost is bounded by policy.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.serve.batcher import bucket_for
from repro.serve.dispatch import ServiceEstimator
from repro.serve.request import Request, TenantPolicy, TokenBucket


@dataclasses.dataclass
class AdmissionDecision:
    admitted: bool
    reason: str = ""                  # one of the gate names when shed
    predicted_wait_s: float = 0.0


class AdmissionController:
    def __init__(self, *, max_batch: int, max_queue: int,
                 estimator: ServiceEstimator,
                 policies: Optional[Dict[str, TenantPolicy]] = None,
                 default_policy: TenantPolicy = TenantPolicy()):
        self.max_batch = max_batch
        self.max_queue = max_queue
        self.estimator = estimator
        self.policies = dict(policies or {})
        self.default_policy = default_policy
        self._buckets: Dict[str, TokenBucket] = {}

    def policy(self, tenant: str) -> TenantPolicy:
        return self.policies.get(tenant, self.default_policy)

    def _bucket(self, tenant: str, now: float) -> TokenBucket:
        tb = self._buckets.get(tenant)
        if tb is None:
            tb = self._buckets[tenant] = TokenBucket(
                self.policy(tenant), now)
        return tb

    def predicted_wait(self, queue_depth: int, busy_remaining_s: float,
                       level) -> float:
        """Time until a request admitted NOW would start executing: the
        in-flight batch's remaining service plus draining the queue ahead
        of it in max-batch bites at current estimates."""
        batches_ahead = math.ceil(queue_depth / self.max_batch)
        return busy_remaining_s + batches_ahead * self.estimator.estimate(
            self.max_batch, level)

    def admit(self, req: Request, now: float, *, queue_depth: int,
              busy_remaining_s: float, level) -> AdmissionDecision:
        """Gate one request; clamps ``req.k`` to the tenant's ``max_k``
        on admission.  Shedding does NOT consume a token (a throttled
        tenant's rejected requests must not push its refill further out)."""
        if queue_depth >= self.max_queue:
            return AdmissionDecision(False, "queue_full")
        wait = self.predicted_wait(queue_depth + 1, busy_remaining_s, level)
        own = self.estimator.estimate(
            bucket_for(queue_depth + 1, self.max_batch), level)
        if now + wait + own > req.deadline:
            return AdmissionDecision(False, "predicted_late", wait)
        if not self._bucket(req.tenant, now).take(now):
            return AdmissionDecision(False, "tenant_throttled", wait)
        req.k = min(req.k, self.policy(req.tenant).max_k)
        return AdmissionDecision(True, "", wait)
