"""Dispatch executors: the boundary between the runtime and the head.

An executor is anything with ``dispatch(x, k, level) -> DispatchResult``:
it serves a padded ``(bucket, D)`` query block at one degradation level
and reports the service time the runtime should charge.  Three layers:

* ``SimExecutor``   — pure latency model, deterministic placeholder
                      results; what the discrete-event soak tests run
                      (no head in the loop, virtual seconds only).
* ``HeadExecutor``  — the real ``ELMOHead`` top-k behind per-(bucket, k,
                      level) jitted programs; charges measured wall time
                      (``RealClock`` serving) or model time (virtual-
                      clock benches, so results are real but timing is
                      deterministic).
* fault wrappers    — ``fault.inject.SlowExecutor`` / ``FailingExecutor``
                      wrap either to inject slowness / transient
                      ``DispatchError`` for the soak tests.

``ServiceEstimator`` is the runtime's *belief* about service times — an
EWMA per (bucket, level) seeded from an affine cost model — feeding the
batcher's force_time and admission's predicted wait.  It deliberately
learns from observed (possibly injected-slow) dispatches so overload
prediction adapts, while the executors' ground truth stays their own.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Tuple

import numpy as np


class DispatchError(RuntimeError):
    """Transient dispatch failure (preempted accelerator, flaky
    interconnect): the runtime retries through ``fault.retry`` with
    jittered backoff; exhaustion times the batch out."""


@dataclasses.dataclass
class DispatchResult:
    vals: np.ndarray          # (bucket, k) f32
    ids: np.ndarray           # (bucket, k) int32
    service_s: float          # seconds the runtime charges for this batch


@dataclasses.dataclass(frozen=True)
class ServiceModel:
    """Affine batch cost: ``base_s + per_row_s·bucket``, scaled by the
    level's relative cost (degraded paths stream fewer label blocks)."""
    base_s: float = 2e-3
    per_row_s: float = 1e-4

    def __call__(self, bucket: int, cost_scale: float = 1.0) -> float:
        return (self.base_s + self.per_row_s * bucket) * cost_scale


class ServiceEstimator:
    """EWMA service-time belief per (bucket, level name).

    Unobserved keys fall back to the seed model so admission and batch
    formation work from the first request; every completed dispatch
    (including injected-slow ones) tightens the belief."""

    def __init__(self, model: ServiceModel = ServiceModel(),
                 alpha: float = 0.3):
        self.model = model
        self.alpha = alpha
        self._ewma: Dict[Tuple[int, str], float] = {}

    def estimate(self, bucket: int, level) -> float:
        got = self._ewma.get((bucket, level.name))
        return self.model(bucket, level.cost_scale) if got is None else got

    def observe(self, bucket: int, level, service_s: float) -> None:
        key = (bucket, level.name)
        prev = self._ewma.get(key, service_s)
        self._ewma[key] = (1 - self.alpha) * prev + self.alpha * service_s


class SimExecutor:
    """Head-free executor for discrete-event tests: service time from a
    ground-truth ``ServiceModel``, results a deterministic function of
    shape only (rank-descending values, ascending ids)."""

    def __init__(self, model: ServiceModel = ServiceModel()):
        self.model = model
        self.calls = 0

    def dispatch(self, x: np.ndarray, k: int, level) -> DispatchResult:
        self.calls += 1
        b = x.shape[0]
        vals = np.broadcast_to(
            np.arange(k, 0, -1, dtype=np.float32), (b, k)).copy()
        ids = np.broadcast_to(np.arange(k, dtype=np.int32), (b, k)).copy()
        return DispatchResult(vals, ids,
                              self.model(b, level.cost_scale))


class HeadExecutor:
    """Real serving through the degradation ladder's ``level.serve``
    callables, one jitted program per (bucket, k, level) — the HeadPlan
    per-bucket program cache the runtime was built around.

    ``timing="measure"`` charges measured wall seconds (RealClock
    serving); ``timing="model"`` charges ``model(bucket, cost_scale)``
    so virtual-clock runs stay deterministic while results are real."""

    def __init__(self, state, *, timing: str = "measure",
                 model: ServiceModel = ServiceModel()):
        assert timing in ("measure", "model"), timing
        self.state = state
        self.timing = timing
        self.model = model
        self.calls = 0
        self._progs: dict = {}

    def _prog(self, k: int, level):
        import jax

        key = (k, level.name)
        fn = self._progs.get(key)
        if fn is None:
            serve = level.serve
            fn = self._progs[key] = jax.jit(
                functools.partial(serve, k=k))
        return fn

    def warmup(self, levels, buckets, ks, d_model: int) -> None:
        """Compile every (bucket, k, level) program up front so the
        first measured dispatch is not a compile."""
        import jax

        for level in levels:
            for b in buckets:
                for k in ks:
                    x = np.zeros((b, d_model), np.float32)
                    jax.block_until_ready(
                        self._prog(k, level)(self.state, x))

    def dispatch(self, x: np.ndarray, k: int, level) -> DispatchResult:
        import jax

        self.calls += 1
        t0 = time.monotonic()
        vals, ids = jax.block_until_ready(
            self._prog(k, level)(self.state, x))
        measured = time.monotonic() - t0
        service = (measured if self.timing == "measure"
                   else self.model(x.shape[0], level.cost_scale))
        return DispatchResult(np.asarray(vals), np.asarray(ids), service)
