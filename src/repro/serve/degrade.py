"""Plan-gated graceful degradation: exact → shortlist → smaller beam.

Under sustained overload the runtime steps down a ladder of serving
levels that trade recall for service time, and climbs back (with
hysteresis) when load drops.  The ladder is *plan-gated*: degraded
levels exist only when the HeadPlan actually resolves the 2-stage
shortlist path for this head (DESIGN.md §11) — a geometry the plan
rejects can never be reached by load pressure — and *recall-gated*:
each shortlist level's recall@k is measured against exact serving on a
probe batch at build time, and levels below the recall floor (PR 7's
0.95 contract) are dropped from the ladder entirely.  Degradation may
shed quality, never correctness: every level is exact on the labels its
beam admits, and the level each request was served at is recorded on
the request and in the metrics transitions log.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class DegradeLevel:
    """One rung: ``serve(state, x, k) -> (vals, ids)`` plus the relative
    cost the service estimator seeds from and the measured recall@k vs
    exact (1.0 for the exact rung)."""
    name: str
    cost_scale: float
    recall: float
    serve: Optional[Callable] = None

    def __repr__(self) -> str:
        return (f"DegradeLevel({self.name}, cost×{self.cost_scale:.3f}, "
                f"recall={self.recall:.3f})")


def sim_ladder(scales: Tuple[float, ...] = (1.0, 0.45, 0.3)
               ) -> List[DegradeLevel]:
    """Head-free ladder for the discrete-event tests: exact plus one
    rung per extra scale, no serve callables (SimExecutor ignores them)."""
    names = ["exact"] + [f"degraded{i}" for i in range(1, len(scales))]
    return [DegradeLevel(n, s, 1.0 if i == 0 else 0.96)
            for i, (n, s) in enumerate(zip(names, scales))]


def build_ladder(head, state, *, k: int, max_batch: int,
                 recall_floor: float = 0.95, probe_x=None,
                 iters: int = 4, seed: int = 0,
                 n_clusters: Optional[int] = None,
                 beam: Optional[int] = None) -> List[DegradeLevel]:
    """The production ladder for an ``ELMOHead`` + state.

    Level 0 serves exact through ``head`` (any attached shortlist is
    overridden off).  If — and only if — a shortlist="on" twin of the
    config plans ``topk_path == "shortlist"``, a balanced-k-means index
    is built from the SERVED weights (PR 7 machinery) and two degraded
    rungs are offered: the plan's full beam, then half beam.  Each rung's
    recall@k is measured on ``probe_x`` (vs exact, ``impl="xla"``) and
    rungs under ``recall_floor`` are discarded — an i.i.d.-random head
    has no cluster structure, so its ladder correctly collapses to
    [exact].  Cost scales come from the §11 work model
    (C·D + beam·(L/C)·D vs L·D per query).

    ``n_clusters``/``beam`` override the plan's index geometry (the gate
    itself is still the plan's): the plan tunes for work, but a ladder
    rung lives or dies by measured recall, and a deployment that swept a
    better (C, beam) for its head should serve it."""
    import dataclasses as _dc

    from repro.head import (build_shortlist_index, get_head,
                            shortlist_recall_at_k)

    def _exact(state, x, k):
        return head.topk(state, x, k, shortlist=None)

    levels = [DegradeLevel("exact", 1.0, 1.0, _exact)]
    cfg = head.cfg
    sl_cfg = _dc.replace(cfg, shortlist="on")
    sl_head = get_head(sl_cfg, batch=max_batch, ctx=head.ctx)
    if sl_head.plan.topk_path != "shortlist":
        return levels                      # plan gate: no degraded path
    index = build_shortlist_index(
        sl_cfg, state,
        n_clusters=n_clusters or sl_head.plan.shortlist_c or None,
        beam=beam or sl_head.plan.shortlist_beam or None,
        iters=iters, seed=seed)
    L, C = cfg.num_labels, index.n_clusters

    def _scale(beam: int) -> float:
        return min(1.0, (C + beam * (L / max(1, C))) / max(1, L))

    def _rung(name: str, idx) -> Optional[DegradeLevel]:
        rec = 1.0
        if probe_x is not None:
            rec = shortlist_recall_at_k(sl_cfg, state, idx, probe_x,
                                        ks=(k,))[k]
        if rec < recall_floor:
            return None

        def serve(state, x, k, _idx=idx):
            return sl_head.topk(state, x, k, shortlist=_idx)

        return DegradeLevel(name, _scale(idx.beam), rec, serve)

    for name, beam in (("shortlist", index.beam),
                       ("shortlist/2", max(1, index.beam // 2))):
        rung = _rung(name, index._replace(beam=beam))
        if rung is not None and rung.cost_scale < levels[-1].cost_scale:
            levels.append(rung)
    return levels


@dataclasses.dataclass
class DegradeController:
    """Hysteretic level selection on the load signal the runtime computes
    at every dispatch decision (predicted drain time / SLO budget).

    Degrades only after ``up_patience`` consecutive observations above
    ``hi``; recovers only after ``down_patience`` consecutive below
    ``lo``.  The dead band (lo < signal < hi) resets neither streak to a
    step, so a load hovering at the threshold cannot flap the ladder —
    that, plus hi > lo, is the hysteresis contract the tests pin."""
    n_levels: int
    hi: float = 1.0
    lo: float = 0.4
    up_patience: int = 3
    down_patience: int = 8
    level: int = 0
    transitions: List[tuple] = dataclasses.field(default_factory=list)
    _hot: int = 0
    _cool: int = 0

    def observe(self, signal: float, now: float) -> int:
        if signal > self.hi:
            self._hot, self._cool = self._hot + 1, 0
        elif signal < self.lo:
            self._hot, self._cool = 0, self._cool + 1
        else:
            self._hot = self._cool = 0
        if self._hot >= self.up_patience and self.level < self.n_levels - 1:
            self.transitions.append(
                (now, self.level, self.level + 1, round(signal, 4)))
            self.level += 1
            self._hot = 0
        elif self._cool >= self.down_patience and self.level > 0:
            self.transitions.append(
                (now, self.level, self.level - 1, round(signal, 4)))
            self.level -= 1
            self._cool = 0
        return self.level
