"""Clocks the serving runtime is written against.

Everything time-shaped in ``repro.serve`` — deadlines, batch-formation
waits, retry backoff, service-time accounting — goes through this one
two-method surface (``now()`` / ``sleep()``), so the SAME runtime code
runs in production against ``RealClock`` and in tests against
``VirtualClock``, where time only moves when the harness says so.  That
is what makes the overload soak tests deterministic: a seeded Poisson
trace replayed on a virtual clock produces bit-identical metrics on
every run and every machine (DESIGN.md §12).
"""
from __future__ import annotations

import time


class VirtualClock:
    """Deterministic simulated time: advances only via ``sleep`` /
    ``advance_to`` — never by itself.  ``advance_to`` is monotone (moving
    "backwards" is a no-op, not an error) so interleaved event sources
    (arrivals, dispatch completions, retry sleeps) cannot fight."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def sleep(self, dt: float) -> None:
        self._now += max(0.0, float(dt))

    def advance_to(self, t: float) -> None:
        self._now = max(self._now, float(t))


class RealClock:
    """Wall time (monotonic, so SLO math survives NTP steps)."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)

    def advance_to(self, t: float) -> None:
        self.sleep(t - self.now())
