"""Requests, terminal outcomes, and per-tenant policy primitives.

The runtime's one hard invariant lives here: **every submitted request
reaches exactly one terminal state** — ``COMPLETED`` (result delivered
within its deadline), ``REJECTED`` (admission shed it before it ever
queued), or ``TIMED_OUT`` (deadline passed while queued, dispatch failed
permanently, or the batch finished too late).  ``Request.finish`` is the
single transition point and asserts the once-ness; the soak tests count
outcomes against submissions to prove nothing is lost or double-counted.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np


class Outcome(enum.Enum):
    COMPLETED = "completed"
    REJECTED = "rejected"
    TIMED_OUT = "timed_out"


@dataclasses.dataclass
class Request:
    """One top-k query with a latency budget.

    ``deadline_s`` is relative to ``submit_t`` (the open-loop generator
    stamps ``submit_t``; admission sees absolute ``deadline``).  ``k`` may
    be clamped down by the tenant's ``max_k`` at admission."""
    rid: int
    tenant: str
    x: np.ndarray                    # (D,) query features
    k: int
    submit_t: float
    deadline_s: float
    # terminal bookkeeping (runtime-owned)
    outcome: Optional[Outcome] = None
    reason: str = ""
    t_terminal: float = float("nan")
    level: str = ""                  # degradation level it was served at
    vals: Optional[np.ndarray] = None
    ids: Optional[np.ndarray] = None

    @property
    def deadline(self) -> float:
        return self.submit_t + self.deadline_s

    @property
    def latency_s(self) -> float:
        return self.t_terminal - self.submit_t

    def finish(self, outcome: Outcome, t: float, reason: str = "") -> None:
        """The ONLY terminal transition — a second call is a runtime bug
        (a lost/double-completed request), not a recoverable condition."""
        assert self.outcome is None, \
            f"request {self.rid} already terminal: {self.outcome}"
        self.outcome = outcome
        self.reason = reason
        self.t_terminal = t


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """Per-tenant admission knobs: a token bucket (``rate_qps`` sustained,
    ``burst`` depth) and a ``max_k`` cap on the served k."""
    rate_qps: float = float("inf")
    burst: float = float("inf")
    max_k: int = 1 << 30


class TokenBucket:
    """Classic token bucket on the runtime clock: ``take`` refills by
    elapsed × rate (capped at ``burst``) then spends one token; an empty
    bucket means the tenant is over its rate and the request is shed."""

    def __init__(self, policy: TenantPolicy, now: float):
        self.policy = policy
        self.tokens = float(policy.burst)
        self._last = now

    def take(self, now: float) -> bool:
        self.tokens = min(float(self.policy.burst),
                          self.tokens
                          + max(0.0, now - self._last) * self.policy.rate_qps)
        self._last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False
