"""The deadline-aware serving runtime (DESIGN.md §12).

A discrete-event server around one accelerator's head: a bounded queue
feeds a deadline-aware batcher that fills the largest precompiled
power-of-two bucket each request's latency budget allows; admission
sheds at the door (predicted wait, per-tenant token buckets); dispatch
goes through ``fault.retry`` with full-jitter backoff on transient
``DispatchError``; and a plan-gated degradation ladder steps exact →
shortlist → smaller beam under sustained overload, and back with
hysteresis when load drops.

Continuous batching: exactly one batch is in flight; arrivals admitted
mid-flight queue up, and the next batch forms the instant the previous
one completes.  The whole engine is event-driven against the injected
clock — ``next_action_time`` names the next instant anything can happen
(in-flight completion, queued-deadline expiry, forced dispatch), and
``run_until``/``drain`` advance the clock exactly there.  On a
``VirtualClock`` this makes every soak replay bit-identical; on a
``RealClock`` the same loop serves wall-clock traffic.

Terminal-state contract: every submitted request reaches exactly one of
COMPLETED / REJECTED / TIMED_OUT (``Request.finish`` asserts once-ness;
``Metrics.conserved`` audits the counts).  Timeouts carry reasons:
``queue_deadline`` (expired while queued, stamped at its own deadline),
``late_completion`` (batch finished past the deadline), and
``dispatch_failed`` (retry budget exhausted on injected/real faults).
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional

import numpy as np

from repro.fault import runtime as FR
from repro.serve.admission import AdmissionController
from repro.serve.batcher import DeadlineBatcher, bucket_for
from repro.serve.clock import VirtualClock
from repro.serve.degrade import DegradeController, DegradeLevel
from repro.serve.dispatch import (DispatchError, ServiceEstimator,
                                  ServiceModel)
from repro.serve.metrics import Metrics
from repro.serve.request import Outcome, Request, TenantPolicy


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Runtime knobs (the README's SLO/degradation table).

    ``slo_s`` is the nominal latency budget the load signal is
    normalized by (requests still carry their own deadlines); the
    degradation ladder engages when the predicted queue-drain time at
    EXACT-path estimates exceeds ``degrade_hi × slo_s`` for
    ``up_patience`` consecutive dispatch decisions, and recovers below
    ``degrade_lo × slo_s`` after ``down_patience`` — the hi > lo band
    plus patience is the anti-flap hysteresis."""
    max_batch: int = 32
    max_queue: int = 256
    slo_s: float = 0.05
    # batch formation waits until earliest_deadline − safety ×
    # svc_estimate: the margin absorbs estimator error so a converged
    # estimate doesn't land completions exactly ON the deadline
    safety: float = 1.25
    dispatch_attempts: int = 3
    retry_base_s: float = 1e-3
    retry_max_s: float = 20e-3
    # load-signal thresholds as fractions of slo_s: degrade when the
    # predicted queue-drain time exceeds half the SLO budget (the other
    # half is the request's own service + safety margin), recover well
    # below it.  Keep max_queue/max_batch × svc(max_batch) above
    # degrade_hi × slo_s or queue_full shedding will cap the signal
    # below the ladder's engage point.
    degrade_hi: float = 0.5
    degrade_lo: float = 0.2
    up_patience: int = 3
    down_patience: int = 6
    seed: int = 0


@dataclasses.dataclass
class _Inflight:
    done_t: float
    batch: List[Request]
    bucket: int
    level: DegradeLevel
    vals: np.ndarray
    ids: np.ndarray


class Server:
    """One serving runtime instance.  Drive it with ``submit`` +
    ``run_until``/``drain`` (or the ``run_trace`` convenience for a
    pre-generated arrival list)."""

    def __init__(self, executor, levels: List[DegradeLevel],
                 clock=None, cfg: ServeConfig = ServeConfig(),
                 policies: Optional[Dict[str, TenantPolicy]] = None,
                 default_policy: TenantPolicy = TenantPolicy(),
                 estimator: Optional[ServiceEstimator] = None):
        assert levels, "need at least the exact level"
        self.executor = executor
        self.levels = levels
        self.clock = clock if clock is not None else VirtualClock()
        self.cfg = cfg
        self.estimator = estimator or ServiceEstimator(ServiceModel())
        self.metrics = Metrics()
        self.admission = AdmissionController(
            max_batch=cfg.max_batch, max_queue=cfg.max_queue,
            estimator=self.estimator, policies=policies,
            default_policy=default_policy)
        self.controller = DegradeController(
            n_levels=len(levels), hi=cfg.degrade_hi, lo=cfg.degrade_lo,
            up_patience=cfg.up_patience, down_patience=cfg.down_patience)
        self._batcher = DeadlineBatcher(cfg.max_queue)
        self._inflight: Optional[_Inflight] = None
        self._rng = random.Random(cfg.seed)   # retry jitter only

    # ---- submission ----

    def submit(self, req: Request):
        """Admit or shed one request at the current clock time.  Returns
        the ``AdmissionDecision`` (shed requests are already terminal)."""
        now = self.clock.now()
        self.metrics.record_submit(now)
        busy = (max(0.0, self._inflight.done_t - now)
                if self._inflight else 0.0)
        dec = self.admission.admit(
            req, now, queue_depth=self._batcher.depth,
            busy_remaining_s=busy,
            level=self.levels[self.controller.level])
        if not dec.admitted:
            req.finish(Outcome.REJECTED, now, dec.reason)
            self.metrics.record_terminal(req)
            return dec
        self._batcher.push(req)
        return dec

    # ---- the event loop ----

    def next_action_time(self) -> Optional[float]:
        """The next instant anything can happen, or None when idle."""
        if self._inflight is not None:
            return self._inflight.done_t
        if self._batcher.depth == 0:
            return None
        now = self.clock.now()
        e = self._batcher.earliest_deadline()
        f = self._force_time()
        return min(e, max(now, f))

    def run_until(self, t: float) -> None:
        """Process every action due at or before ``t`` (the clock ends
        ≤ t; the caller advances it to t for same-instant arrivals)."""
        while True:
            ta = self.next_action_time()
            if ta is None or ta > t:
                return
            self.clock.advance_to(ta)
            self._on_timer()

    def drain(self) -> None:
        """Run to quiescence: no batch in flight, nothing queued."""
        while True:
            ta = self.next_action_time()
            if ta is None:
                return
            self.clock.advance_to(ta)
            self._on_timer()

    # ---- internals ----

    def _svc(self, bucket: int, level: DegradeLevel) -> float:
        return self.estimator.estimate(bucket, level)

    def _force_time(self) -> float:
        level = self.levels[self.controller.level]
        f = self._batcher.force_time(
            lambda b: self.cfg.safety * self._svc(b, level),
            self.cfg.max_batch)
        return 0.0 if f is None else f

    def _on_timer(self) -> None:
        now = self.clock.now()
        if self._inflight is not None:
            if now < self._inflight.done_t:
                return
            self._complete(self._inflight.done_t)
        for r in self._batcher.sweep_expired(now):
            r.finish(Outcome.TIMED_OUT, r.deadline, "queue_deadline")
            self.metrics.record_terminal(r)
        if self._batcher.depth and now >= self._force_time():
            self._dispatch(now)

    def _signal(self, depth: int) -> float:
        """Load signal for the degradation controller: predicted time to
        drain the whole queue at EXACT-path estimates (so a degraded
        ladder does not lower its own signal and flap), over the SLO."""
        drain = math.ceil(depth / self.cfg.max_batch) \
            * self._svc(self.cfg.max_batch, self.levels[0])
        return drain / self.cfg.slo_s

    def _dispatch(self, now: float) -> None:
        depth = self._batcher.depth
        self.metrics.record_depth(depth)
        prev = self.controller.level
        lvl = self.controller.observe(self._signal(depth), now)
        if lvl != prev:
            self.metrics.record_transition(now, prev, lvl,
                                           self.controller.transitions[-1][3])
        level = self.levels[lvl]
        batch = self._batcher.take(self.cfg.max_batch)
        bucket = bucket_for(len(batch), self.cfg.max_batch)
        k_hat = max(r.k for r in batch)
        xs = np.zeros((bucket, batch[0].x.shape[0]), np.float32)
        for i, r in enumerate(batch):
            xs[i] = r.x
        calls = {"n": 0}

        def call():
            calls["n"] += 1
            return self.executor.dispatch(xs, k_hat, level)

        try:
            res = FR.retry(call, attempts=self.cfg.dispatch_attempts,
                           base_delay_s=self.cfg.retry_base_s,
                           retriable=(DispatchError,),
                           sleep=self.clock.sleep, jitter="full",
                           max_delay_s=self.cfg.retry_max_s, rng=self._rng)
        except DispatchError:
            t = self.clock.now()     # backoff time already charged
            for r in batch:
                r.finish(Outcome.TIMED_OUT, t, "dispatch_failed")
                self.metrics.record_terminal(r)
            return
        t_start = self.clock.now()
        self.estimator.observe(bucket, level, res.service_s)
        self.metrics.record_dispatch(
            bucket=bucket, n_real=len(batch), level=level.name,
            service_s=res.service_s, retries=calls["n"] - 1)
        self._inflight = _Inflight(t_start + res.service_s, batch, bucket,
                                   level, np.asarray(res.vals),
                                   np.asarray(res.ids))

    def _complete(self, t: float) -> None:
        inf, self._inflight = self._inflight, None
        for i, r in enumerate(inf.batch):
            if t > r.deadline:
                r.finish(Outcome.TIMED_OUT, t, "late_completion")
            else:
                r.vals = inf.vals[i, :r.k].copy()
                r.ids = inf.ids[i, :r.k].copy()
                r.level = inf.level.name
                r.finish(Outcome.COMPLETED, t)
            self.metrics.record_terminal(r)


def run_trace(server: Server, requests: List[Request]) -> Metrics:
    """Replay a pre-generated arrival trace (e.g. from
    ``fault.inject.poisson_requests``) to quiescence.  Actions due at an
    arrival's instant run before the arrival (a completion at t frees
    the server for a request arriving at t); the returned metrics are a
    pure function of (trace, server config, executor) on a virtual
    clock."""
    for req in sorted(requests, key=lambda r: (r.submit_t, r.rid)):
        server.run_until(req.submit_t)
        server.clock.advance_to(req.submit_t)
        server.submit(req)
    server.drain()
    return server.metrics
