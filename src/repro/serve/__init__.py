"""``repro.serve`` — the production-shaped serving runtime (DESIGN.md §12).

What ``launch/serve.py --bench`` measures, this package operates: an
event-driven request loop with continuous batching over the HeadPlan's
precompiled power-of-two bucket programs, admission control with
explicit REJECTED outcomes, per-request deadlines, retry-backed
dispatch, and a plan-gated graceful-degradation ladder (exact →
shortlist → smaller beam, and back with hysteresis).  Deterministic by
construction: the same runtime code runs against a ``VirtualClock`` in
the fault-injected soak tests and a ``RealClock`` in production.

    from repro import serve
    from repro.fault import inject as FI

    levels = serve.build_ladder(head, state, k=5, max_batch=32)
    ex = serve.HeadExecutor(state, timing="model")
    srv = serve.Server(ex, levels, cfg=serve.ServeConfig(slo_s=0.05))
    reqs = FI.poisson_requests(rate_qps=500, horizon_s=2.0, seed=0,
                               d_model=head.cfg.d_model)
    report = serve.run_trace(srv, reqs).report()
"""
from __future__ import annotations

from repro.serve.admission import AdmissionController, AdmissionDecision
from repro.serve.batcher import DeadlineBatcher, bucket_for
from repro.serve.clock import RealClock, VirtualClock
from repro.serve.degrade import (DegradeController, DegradeLevel,
                                 build_ladder, sim_ladder)
from repro.serve.dispatch import (DispatchError, DispatchResult,
                                  HeadExecutor, ServiceEstimator,
                                  ServiceModel, SimExecutor)
from repro.serve.metrics import Metrics, percentile
from repro.serve.request import (Outcome, Request, TenantPolicy,
                                 TokenBucket)
from repro.serve.runtime import ServeConfig, Server, run_trace

__all__ = [
    "AdmissionController", "AdmissionDecision", "DeadlineBatcher",
    "DegradeController", "DegradeLevel", "DispatchError",
    "DispatchResult", "HeadExecutor", "Metrics", "Outcome", "RealClock",
    "Request", "ServeConfig", "Server", "ServiceEstimator",
    "ServiceModel", "SimExecutor", "TenantPolicy", "TokenBucket",
    "VirtualClock", "bucket_for", "build_ladder", "percentile",
    "run_trace", "sim_ladder",
]
