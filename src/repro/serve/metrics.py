"""Serving metrics: latency percentiles, QPS, shed rate, queue depth,
degradation transitions — and the conservation check the soak tests
gate on (submitted == completed + rejected + timed_out, exactly).

Everything is recorded against the runtime clock (virtual in tests), so
a seeded soak produces a bit-identical report on every run — the report
itself is the deterministic artifact BENCH_8.json stores.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.serve.request import Outcome, Request


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) — no interpolation, so a
    reported p99 is a latency some request actually saw."""
    if not xs:
        return float("nan")
    s = sorted(xs)
    rank = max(1, -(-len(s) * q // 100))     # ceil(n·q/100), ≥ 1
    return s[int(rank) - 1]


class Metrics:
    def __init__(self):
        self.submitted = 0
        self.counts: Dict[Outcome, int] = {o: 0 for o in Outcome}
        self.reasons: Dict[str, int] = {}
        self.latency: Dict[Outcome, List[float]] = {o: [] for o in Outcome}
        self.met_deadline = 0                 # completed within deadline
        self.admitted = 0
        self.dispatches = 0
        self.dispatch_retries = 0
        self.rows_real = 0
        self.rows_padded = 0
        self.level_dispatches: Dict[str, int] = {}
        self.depth_samples: List[int] = []
        self.transitions: List[tuple] = []    # (t, from, to, signal)
        self._t0: Optional[float] = None
        self._t1: Optional[float] = None

    # ---- recording ----

    def _span(self, t: float) -> None:
        self._t0 = t if self._t0 is None else min(self._t0, t)
        self._t1 = t if self._t1 is None else max(self._t1, t)

    def record_submit(self, t: float) -> None:
        self.submitted += 1
        self._span(t)

    def record_terminal(self, req: Request) -> None:
        o = req.outcome
        assert o is not None
        self.counts[o] += 1
        if req.reason:
            self.reasons[req.reason] = self.reasons.get(req.reason, 0) + 1
        self.latency[o].append(req.latency_s)
        if o is not Outcome.REJECTED:
            self.admitted += 1
        if o is Outcome.COMPLETED and req.t_terminal <= req.deadline:
            self.met_deadline += 1
        self._span(req.t_terminal)

    def record_dispatch(self, *, bucket: int, n_real: int, level: str,
                        service_s: float, retries: int) -> None:
        self.dispatches += 1
        self.dispatch_retries += retries
        self.rows_real += n_real
        self.rows_padded += bucket
        self.level_dispatches[level] = \
            self.level_dispatches.get(level, 0) + 1

    def record_depth(self, depth: int) -> None:
        self.depth_samples.append(depth)

    def record_transition(self, t: float, frm: int, to: int,
                          signal: float) -> None:
        self.transitions.append((t, frm, to, signal))

    # ---- report ----

    def conserved(self) -> bool:
        return self.submitted == sum(self.counts.values())

    def report(self) -> dict:
        done = self.latency[Outcome.COMPLETED]
        horizon = ((self._t1 - self._t0)
                   if self._t0 is not None and self._t1 > self._t0 else 0.0)
        n = max(1, self.submitted)
        return {
            "submitted": self.submitted,
            "completed": self.counts[Outcome.COMPLETED],
            "rejected": self.counts[Outcome.REJECTED],
            "timed_out": self.counts[Outcome.TIMED_OUT],
            "conserved": self.conserved(),
            "reasons": dict(sorted(self.reasons.items())),
            "p50_ms": 1e3 * percentile(done, 50),
            "p95_ms": 1e3 * percentile(done, 95),
            "p99_ms": 1e3 * percentile(done, 99),
            "qps": (self.counts[Outcome.COMPLETED] / horizon
                    if horizon else 0.0),
            "shed_rate": self.counts[Outcome.REJECTED] / n,
            "timeout_rate": self.counts[Outcome.TIMED_OUT] / n,
            "deadline_met_of_admitted": (self.met_deadline
                                         / max(1, self.admitted)),
            "dispatches": self.dispatches,
            "dispatch_retries": self.dispatch_retries,
            "fill": self.rows_real / max(1, self.rows_padded),
            "level_dispatches": dict(sorted(
                self.level_dispatches.items())),
            "max_depth": max(self.depth_samples, default=0),
            "mean_depth": (sum(self.depth_samples)
                           / max(1, len(self.depth_samples))),
            "transitions": list(self.transitions),
            "horizon_s": horizon,
        }
