"""Deadline-aware dynamic batching over the precompiled bucket programs.

The HeadPlan facade compiles one top-k program per power-of-two batch
bucket (``launch.serve._buckets`` — whose sizing now lives here as
``bucket_for`` so the bench and the runtime share one definition).  The
batcher exploits the padding that buckets already pay for: a queue of n
requests dispatches as a ``bucket_for(n)``-row program, so waiting for
more arrivals is FREE until the queue crosses the next power of two —
the batcher therefore waits exactly as long as the earliest deadline
allows (``force_time``), filling the largest bucket each request's
latency budget admits, and dispatches the moment slack runs out or the
max bucket fills.
"""
from __future__ import annotations

from typing import Callable, List, Optional

from repro.serve.request import Request


def bucket_for(size: int, max_batch: int) -> int:
    """The power-of-two padded-bucket width for ``size`` queries — the
    exact ``launch.serve._buckets`` semantics: the smallest power of two
    ≥ min(size, max_batch), capped at ``max_batch`` (so a non-power-of-two
    cap is itself the top bucket)."""
    b = 1
    while b < min(int(size), max_batch):
        b *= 2
    return min(b, max_batch)


class DeadlineBatcher:
    """Bounded FIFO queue + EDF batch formation.

    The queue is arrival-ordered; batches are taken earliest-deadline-
    first so under pressure the requests closest to their SLO ride the
    next dispatch.  Expiry (``sweep_expired``) is the batcher's half of
    the TIMED_OUT contract: a request whose deadline passes while still
    queued leaves through exactly one door, stamped at its own deadline
    (not at whenever the runtime happened to look)."""

    def __init__(self, max_queue: int):
        self.max_queue = max_queue
        self._q: List[Request] = []

    @property
    def depth(self) -> int:
        return len(self._q)

    @property
    def full(self) -> bool:
        return len(self._q) >= self.max_queue

    def push(self, req: Request) -> None:
        assert not self.full, "admission must gate queue_full before push"
        self._q.append(req)

    def sweep_expired(self, now: float) -> List[Request]:
        """Pop (still queued, past deadline) requests; caller finishes
        them TIMED_OUT at their own deadline."""
        dead = [r for r in self._q if r.deadline <= now]
        if dead:
            self._q = [r for r in self._q if r.deadline > now]
        return dead

    def earliest_deadline(self) -> Optional[float]:
        return min((r.deadline for r in self._q), default=None)

    def force_time(self, svc_est: Callable[[int], float],
                   max_batch: int) -> Optional[float]:
        """Latest moment dispatch can wait: the earliest queued deadline
        minus the estimated service of the bucket the current queue would
        dispatch as.  Before this, waiting grows the batch for free (the
        bucket pads to a power of two anyway); after it, the earliest
        request would miss.  A full max bucket forces immediately."""
        if not self._q:
            return None
        if len(self._q) >= max_batch:
            return 0.0                       # dispatch now
        b = bucket_for(len(self._q), max_batch)
        return self.earliest_deadline() - svc_est(b)

    def take(self, max_batch: int) -> List[Request]:
        """Pop up to ``max_batch`` requests, earliest deadline first
        (ties broken by arrival order — Python's sort is stable)."""
        self._q.sort(key=lambda r: r.deadline)
        batch, self._q = self._q[:max_batch], self._q[max_batch:]
        return batch
