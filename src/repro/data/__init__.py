"""Deterministic, host-sharded data pipeline."""
from repro.data.pipeline import (DataCursor, lm_batches, synthetic_xmc,
                                 xmc_batches)

__all__ = ["DataCursor", "lm_batches", "xmc_batches", "synthetic_xmc"]
