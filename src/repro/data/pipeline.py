"""Data pipeline: synthetic XMC + LM token streams, deterministic resume.

Production properties:

* **Host-sharded**: every host computes only its slice of the global batch
  (``host_id``/``n_hosts``); batches are pure functions of (seed, step) so
  no coordination or file-offset state is needed.
* **Deterministic resume**: a ``DataCursor`` (seed, step) is stored in every
  checkpoint manifest; restoring it reproduces the exact batch sequence —
  including after elastic re-sharding (the global batch is always generated
  from the global step and then sliced by the *current* host topology).
* **Power-law labels** for XMC (the long-tailed distribution that motivates
  the paper's head-Kahan hybrid, App. D): label frequency ∝ rank^-1.0, so
  "head label" chunks are genuinely hot.

Real deployments replace the synthetic generators with tokenized shards on
disk; the cursor/sharding contract stays identical.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class DataCursor:
    seed: int = 0
    step: int = 0

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_state(cls, d: dict) -> "DataCursor":
        return cls(seed=int(d["seed"]), step=int(d["step"]))


def _rng_for(cursor: DataCursor) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cursor.seed, cursor.step]))


def _host_slice(global_batch: int, host_id: int, n_hosts: int) -> slice:
    assert global_batch % n_hosts == 0
    per = global_batch // n_hosts
    return slice(host_id * per, (host_id + 1) * per)


def lm_batches(vocab: int, global_batch: int, seq: int, cursor: DataCursor,
               host_id: int = 0, n_hosts: int = 1) -> Iterator[dict]:
    """Synthetic LM stream: tokens (B, S) + next-token targets (B, S).

    Each batch carries ``cursor`` (the state that *generated* it) and
    ``next_cursor`` (the state of the batch after it).  Checkpoints must
    store ``next_cursor``: a restore replays the first *unconsumed* batch,
    not the one the saved step already trained on."""
    sl = _host_slice(global_batch, host_id, n_hosts)
    while True:
        rng = _rng_for(cursor)
        nxt = DataCursor(cursor.seed, cursor.step + 1)
        toks = rng.integers(0, vocab, (global_batch, seq + 1), dtype=np.int32)
        yield {"tokens": toks[sl, :-1], "targets": toks[sl, 1:],
               "cursor": cursor.state(), "next_cursor": nxt.state()}
        cursor = nxt


def synthetic_xmc(rng: np.random.Generator, batch: int, seq: int, vocab: int,
                  num_labels: int, max_pos: int, zipf_a: float = 1.0):
    """One XMC batch: token text + power-law multi-label targets."""
    toks = rng.integers(0, vocab, (batch, seq), dtype=np.int32)
    # label frequency ∝ rank^-zipf_a over [0, num_labels)
    u = rng.random((batch, max_pos))
    ranks = np.minimum((num_labels ** u - 1), num_labels - 1).astype(np.int32)
    n_pos = rng.integers(1, max_pos + 1, (batch,))
    mask = np.arange(max_pos)[None, :] < n_pos[:, None]
    labels = np.where(mask, ranks, -1).astype(np.int32)
    return toks, labels


def xmc_batches(vocab: int, num_labels: int, global_batch: int, seq: int,
                max_pos: int, cursor: DataCursor, host_id: int = 0,
                n_hosts: int = 1) -> Iterator[dict]:
    sl = _host_slice(global_batch, host_id, n_hosts)
    while True:
        rng = _rng_for(cursor)
        nxt = DataCursor(cursor.seed, cursor.step + 1)
        toks, labels = synthetic_xmc(rng, global_batch, seq, vocab,
                                     num_labels, max_pos)
        yield {"tokens": toks[sl], "targets": labels[sl],
               "cursor": cursor.state(), "next_cursor": nxt.state()}
        cursor = nxt
