"""Fault injection: the drivers tests use to *prove* the runtime is fault
tolerant, instead of trusting it.

* ``run_and_kill``       — subprocess driver: launch a training CLI, watch
                           its heartbeat file, SIGKILL it the moment it
                           reaches step N (a real preemption: no atexit, no
                           flush, in-flight async checkpoint writes torn).
* ``bit_flip_leaf`` /    — checkpoint corruption: flip one bit in a
  ``truncate_leaf`` /      committed leaf, tear a leaf mid-file, or tear
  ``truncate_manifest``    the manifest itself.  Restore must detect all
                           three via the manifest checksums and fall back.
* ``write_heartbeat`` /  — simulated fleet: beat for hosts that do not
  ``make_stale``           exist in this single-process harness, then age
                           one past the timeout to trigger ``HostFailure``.
* ``FlakyBatches``       — transient data-pipeline errors: raises on
                           scheduled fetches, then recovers — the train
                           loop's ``retry`` wrapper must absorb it without
                           skipping or duplicating a batch.

Serving side (DESIGN.md §12 — the runtime soak tests):

* ``SlowExecutor`` /     — wrap a ``repro.serve`` dispatch executor to
  ``FailingExecutor``      inflate scheduled dispatches' service time
                           (a straggling accelerator) or raise transient
                           ``DispatchError`` (a preemption) — the
                           runtime must degrade/retry, never lose a
                           request.
* ``poisson_requests``   — seeded OPEN-LOOP Poisson load: arrival times
                           are independent of completions (the honest
                           overload model — real users don't slow down
                           because your server did), stamped in virtual
                           seconds so soaks replay bit-identically.
* ``torn_heartbeat``     — the empty-but-renamed heartbeat a crash
                           could publish before ``Heartbeat.beat``
                           fsynced (readers must treat it as absent,
                           not as a dead host).
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import subprocess
import sys
import time
from typing import Iterator, List, Optional, Sequence


# ---------------------------------------------------------------------------
# kill-at-step-N subprocess driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KillResult:
    killed: bool               # True iff we SIGKILLed it (vs ran to exit)
    step_seen: int             # last heartbeat step observed
    returncode: Optional[int]
    stdout: str
    stderr: str


def _read_hb_step(hb_file: str) -> Optional[int]:
    try:
        with open(hb_file) as f:
            return int(json.load(f)["step"])
    except (FileNotFoundError, json.JSONDecodeError, KeyError, ValueError):
        return None            # not written yet / mid-replace


def run_and_kill(argv: Sequence[str], *, hb_file: str, kill_step: int,
                 env: Optional[dict] = None, poll_s: float = 0.02,
                 timeout_s: float = 600.0) -> KillResult:
    """Launch ``argv``, poll its heartbeat file, SIGKILL at ``kill_step``.

    The heartbeat is the same file the fault runtime watches
    (``<ckpt_dir>/hb/host_0000.hb``), so the kill lands mid-step — after
    the step's compute, possibly mid-checkpoint-write.  Returns a
    ``KillResult``; ``killed=False`` means the run finished first."""
    proc = subprocess.Popen(list(argv), env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)
    deadline = time.time() + timeout_s
    step_seen = -1
    killed = False
    while proc.poll() is None:
        if time.time() > deadline:
            proc.kill()
            out, err = proc.communicate()
            raise TimeoutError(f"run_and_kill: {timeout_s}s elapsed before "
                               f"step {kill_step} (saw {step_seen})\n"
                               + out[-2000:] + err[-2000:])
        step = _read_hb_step(hb_file)
        if step is not None:
            step_seen = step
            if step >= kill_step:
                proc.send_signal(signal.SIGKILL)
                killed = True
                break
        time.sleep(poll_s)
    out, err = proc.communicate()
    return KillResult(killed=killed, step_seen=step_seen,
                      returncode=proc.returncode, stdout=out, stderr=err)


def train_argv(*args: str) -> List[str]:
    """``python -m repro.launch.train <args>`` with this interpreter."""
    return [sys.executable, "-m", "repro.launch.train", *args]


def subprocess_env(repo_src: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = repo_src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env


# ---------------------------------------------------------------------------
# checkpoint corruption
# ---------------------------------------------------------------------------


def _leaf_file(ckpt_path: str, leaf_index: int) -> str:
    with open(os.path.join(ckpt_path, "manifest.json")) as f:
        manifest = json.load(f)
    return os.path.join(ckpt_path, manifest["leaves"][leaf_index]["file"])


def bit_flip_leaf(ckpt_path: str, leaf_index: int = 0,
                  byte_offset: Optional[int] = None, bit: int = 3) -> str:
    """Flip one bit in a committed leaf file (silent storage corruption —
    undetectable without the manifest checksums).  Returns the file."""
    fname = _leaf_file(ckpt_path, leaf_index)
    with open(fname, "r+b") as f:
        data = bytearray(f.read())
        # default: a payload byte well past the .npy header
        off = byte_offset if byte_offset is not None else len(data) - 1
        data[off] ^= (1 << bit)
        f.seek(0)
        f.write(data)
    return fname


def truncate_leaf(ckpt_path: str, leaf_index: int = 0,
                  keep_fraction: float = 0.5) -> str:
    """Tear a leaf write: keep only the leading fraction of the file."""
    fname = _leaf_file(ckpt_path, leaf_index)
    size = os.path.getsize(fname)
    with open(fname, "r+b") as f:
        f.truncate(max(1, int(size * keep_fraction)))
    return fname


def truncate_manifest(ckpt_path: str, keep_bytes: int = 40) -> str:
    """Tear the manifest itself (crash between leaf and manifest fsync)."""
    fname = os.path.join(ckpt_path, "manifest.json")
    with open(fname, "r+b") as f:
        f.truncate(keep_bytes)
    return fname


# ---------------------------------------------------------------------------
# simulated fleet heartbeats
# ---------------------------------------------------------------------------


def write_heartbeat(hb_dir: str, host: int, step: int,
                    t: Optional[float] = None) -> None:
    """Beat on behalf of a simulated peer host."""
    os.makedirs(hb_dir, exist_ok=True)
    path = os.path.join(hb_dir, f"host_{host:04d}.hb")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"step": step, "t": time.time() if t is None else t}, f)
    os.replace(tmp, path)


def make_stale(hb_dir: str, host: int, age_s: float = 1e6) -> None:
    """Age a peer's heartbeat past any timeout — a dead/preempted host."""
    write_heartbeat(hb_dir, host, step=0, t=time.time() - age_s)


def torn_heartbeat(hb_dir: str, host: int) -> str:
    """Publish an EMPTY heartbeat file — what a crash between rename and
    data reaching disk used to leave behind (``Heartbeat.beat`` now
    fsyncs before ``os.replace`` so it cannot happen anew; readers must
    still tolerate the artifact from an old binary: an empty record
    means "never beaten", not "dead host at t=0")."""
    os.makedirs(hb_dir, exist_ok=True)
    path = os.path.join(hb_dir, f"host_{host:04d}.hb")
    with open(path, "w"):
        pass
    return path


# ---------------------------------------------------------------------------
# transient data-pipeline errors
# ---------------------------------------------------------------------------


class FlakyBatches:
    """Wrap a batch iterator with scheduled transient failures.

    ``fail_fetches`` indexes the *fetch attempts* (0-based) that raise;
    the underlying iterator is only advanced on success, so a retried
    fetch yields exactly the batch an unfailed run would have seen."""

    def __init__(self, inner: Iterator[dict], fail_fetches: Sequence[int],
                 exc: type = OSError):
        self._inner = inner
        self._fail = set(fail_fetches)
        self._exc = exc
        self._fetches = 0

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        i = self._fetches
        self._fetches += 1
        if i in self._fail:
            raise self._exc(f"injected transient data error (fetch {i})")
        return next(self._inner)


# ---------------------------------------------------------------------------
# numeric fault injection (DESIGN.md §14 — the numerics-guard soak tests)
# ---------------------------------------------------------------------------


def _poison_flat(leaf, flat_index: int, value: float):
    """Overwrite one element (by flattened index) of a device array,
    round-tripping through f32 so the poison value lands in any storage
    dtype (e4m3's NaN encoding, bf16's max, ...)."""
    import jax.numpy as jnp
    import numpy as np

    arr = np.asarray(leaf.astype(jnp.float32)).copy()
    arr.reshape(-1)[flat_index % arr.size] = value
    return jnp.asarray(arr).astype(leaf.dtype)


def nan_poison_head(state, *, flat_index: int = 0):
    """NaN-poison one head-weight element of a ``launch.steps.TrainState``
    (dense W, or the sparse value stream).  The next step's logits row goes
    non-finite — what a bad DMA / bit-flipped activation looks like — and
    the guard must trip on ``nonfinite_z`` / ``nonfinite_loss``."""
    head = state.head
    if hasattr(head, "values"):
        head = head._replace(
            values=_poison_flat(head.values, flat_index, float("nan")))
    else:
        head = head._replace(
            w=_poison_flat(head.w, flat_index, float("nan")))
    return state._replace(head=head)


def saturate_head(state, *, fraction: float = 0.5, magnitude: float = 450.0):
    """Force-saturate the head's update stream: set the leading
    ``fraction`` of the Kahan compensation to ``magnitude``, chosen to
    push every poisoned element's pre-cast sum just past the FP8 cliff —
    into e4m3's [448, 464) band, which still *rounds down* to ±448 (past
    ~464 the cast overflows to NaN, a different failure).  The weights
    silently pile onto the cliff, the loss stays finite, and ONLY the
    in-kernel saturation counter sees it: the fraction must cross
    ``guard_sat_frac`` on the very next step.  Requires a Kahan head
    (``comp is not None``)."""
    import jax.numpy as jnp
    import numpy as np

    head = state.head
    assert head.comp is not None, "saturate_head needs a Kahan head"
    arr = np.asarray(head.comp.astype(jnp.float32)).copy()
    flat = arr.reshape(-1)
    flat[:max(1, int(flat.size * fraction))] = magnitude
    return state._replace(head=head._replace(
        comp=jnp.asarray(arr).astype(head.comp.dtype)))


def at_step(step: int, mutate, **kw):
    """Adapt a state mutator into a ``train(inject=...)`` hook that fires
    exactly once, before ``step``."""
    def hook(i, state):
        return mutate(state, **kw) if i == step else state
    return hook


def lr_spike(head_lr: float, *, step: int, factor: float = 64.0):
    """A one-step learning-rate spike schedule (returns ``i -> lr``): the
    optimizer briefly runs ``factor``× hot — the loss-spike / divergence
    failure mode the EWMA z-score detector exists for."""
    def sched(i: int) -> float:
        return head_lr * (factor if i == step else 1.0)
    return sched


# ---------------------------------------------------------------------------
# serving-side injection (DESIGN.md §12)
# ---------------------------------------------------------------------------


class SlowExecutor:
    """Wrap a serve executor: scheduled dispatches (0-based *attempt*
    indices, counted across retries) report their service time inflated
    ``factor``× — a straggling accelerator / noisy neighbor.  The result
    payload is untouched: slowness must cost deadlines, not answers."""

    def __init__(self, inner, slow_calls: Sequence[int],
                 factor: float = 10.0):
        self.inner = inner
        self._slow = set(slow_calls)
        self.factor = factor
        self.calls = 0

    def dispatch(self, x, k: int, level):
        i = self.calls
        self.calls += 1
        res = self.inner.dispatch(x, k, level)
        if i in self._slow:
            res = dataclasses.replace(res,
                                      service_s=res.service_s * self.factor)
        return res


class FailingExecutor:
    """Wrap a serve executor: scheduled dispatch attempts raise a
    transient ``DispatchError`` (preempted device, flaky interconnect).
    The runtime's ``fault.retry`` wrapper must absorb isolated failures;
    ``dispatch_attempts`` consecutive indices exhaust the retry budget
    and must surface as TIMED_OUT(dispatch_failed) — never as a lost
    request."""

    def __init__(self, inner, fail_calls: Sequence[int], exc=None):
        from repro.serve.dispatch import DispatchError

        self.inner = inner
        self._fail = set(fail_calls)
        self._exc = exc or DispatchError
        self.calls = 0

    def dispatch(self, x, k: int, level):
        i = self.calls
        self.calls += 1
        if i in self._fail:
            raise self._exc(f"injected transient dispatch failure "
                            f"(attempt {i})")
        return self.inner.dispatch(x, k, level)


def poisson_requests(*, rate_qps: float, horizon_s: float, seed: int,
                     d_model: int, k: int = 5, deadline_s: float = 0.05,
                     tenants: Sequence[str] = ("default",),
                     t0: float = 0.0, rid0: int = 0) -> list:
    """Seeded open-loop Poisson arrivals for the virtual-clock soaks.

    Exponential inter-arrival gaps at ``rate_qps`` over ``horizon_s``
    virtual seconds starting at ``t0``; each request draws i.i.d. normal
    features and a round-robin-by-draw tenant.  Open loop: the trace is
    generated up front and never reacts to the server, so overload stays
    overload.  Compose segments (base → burst → recovery) by chaining
    calls with increasing ``t0``/``rid0`` and distinct seeds; one
    (seed, rate, horizon) tuple always yields one bit-identical trace.
    """
    import numpy as np

    from repro.serve.request import Request

    rng = np.random.default_rng(seed)
    out = []
    t = t0
    rid = rid0
    while True:
        t += float(rng.exponential(1.0 / rate_qps))
        if t >= t0 + horizon_s:
            break
        out.append(Request(
            rid=rid, tenant=tenants[int(rng.integers(len(tenants)))],
            x=rng.standard_normal(d_model).astype(np.float32), k=k,
            submit_t=t, deadline_s=deadline_s))
        rid += 1
    return out
