"""Launcher-level fault tolerance.

JAX SPMD programs are lock-step: a dead or slow host stalls the whole job.
Recovery therefore lives OUTSIDE the compiled step, in the launcher:

* ``Heartbeat``          — each host touches a per-host file (or KV entry)
                           every step; the controller treats a stale
                           heartbeat as a failed host.
* ``StragglerMonitor``   — per-step wall-time EWMA; hosts persistently above
                           ``threshold ×`` the fleet median are flagged for
                           preemptive replacement (checkpoint → drop →
                           rejoin), which beats waiting for a hard failure.
* ``ElasticController``  — the restart policy: on failure, restore the last
                           committed checkpoint and rebuild the mesh with
                           the surviving host count (the data axis shrinks;
                           checkpoints are mesh-independent so restore just
                           reshards — see repro.checkpoint).
* ``retry``              — exponential-backoff wrapper for transient errors
                           (preempted TPU, flaky interconnect init).

These are deliberately simple, dependency-free primitives with the same
control contract as production setups (GKE + TPU provisioner, Borg, etc.);
tests drive them with simulated failures.
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import time
from typing import Callable, Dict, List, Optional


class HostFailure(RuntimeError):
    """A peer's heartbeat went stale mid-run.  Raised from inside the train
    loop (launch/train.py); the elastic driver catches it, plans the
    shrunken fleet with ``ElasticController`` and re-enters training from
    the last committed checkpoint."""

    def __init__(self, dead: List[int], alive: List[int], step: int,
                 losses: Optional[List[float]] = None):
        super().__init__(f"hosts {dead} failed at step {step} "
                         f"(alive: {alive})")
        self.dead = dead
        self.alive = alive
        self.step = step
        self.losses = losses or []


class Heartbeat:
    """File-based heartbeat (stands in for a distributed KV store)."""

    def __init__(self, directory: str, host_id: int, timeout_s: float = 60.0):
        self.dir = directory
        self.host_id = host_id
        self.timeout_s = timeout_s
        os.makedirs(directory, exist_ok=True)

    def _path(self, host: int) -> str:
        return os.path.join(self.dir, f"host_{host:04d}.hb")

    def beat(self, step: int, now: Optional[float] = None) -> None:
        # fsync BEFORE the rename — the §10 checkpoint commit protocol.
        # Without it a crash can publish an empty-but-renamed heartbeat
        # (rename durable, data not), which reads as a dead host and
        # triggers a spurious elastic restart.
        tmp = self._path(self.host_id) + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "t": now or time.time()}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._path(self.host_id))

    def _read(self, host: int) -> Optional[dict]:
        try:
            with open(self._path(host)) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def records(self, n_hosts: int) -> Dict[int, dict]:
        """Latest ``{"step", "t"}`` record per host that has ever beaten
        (feeds ``StragglerMonitor`` with peer step times)."""
        out = {}
        for h in range(n_hosts):
            rec = self._read(h)
            if rec is not None:
                out[h] = rec
        return out

    def alive_hosts(self, n_hosts: int, now: Optional[float] = None
                    ) -> List[int]:
        now = now or time.time()
        return [h for h, rec in self.records(n_hosts).items()
                if now - rec["t"] <= self.timeout_s]


@dataclasses.dataclass
class StragglerMonitor:
    """Flags hosts whose step time EWMA exceeds threshold × fleet median."""
    threshold: float = 1.5
    alpha: float = 0.2
    ewma: Dict[int, float] = dataclasses.field(default_factory=dict)

    def record(self, host: int, step_time_s: float) -> None:
        prev = self.ewma.get(host, step_time_s)
        self.ewma[host] = (1 - self.alpha) * prev + self.alpha * step_time_s

    def stragglers(self) -> List[int]:
        if len(self.ewma) < 2:
            return []
        vals = sorted(self.ewma.values())
        n = len(vals)
        # true median: the upper-middle element alone (the old
        # ``vals[n // 2]``) inflates the fleet baseline on even counts —
        # in a 4-host fleet with one straggler it puts the straggler-
        # adjacent host in the denominator and hides the straggler
        med = vals[n // 2] if n % 2 else \
            0.5 * (vals[n // 2 - 1] + vals[n // 2])
        return [h for h, t in self.ewma.items() if t > self.threshold * med]


def retry(fn: Callable, attempts: int = 3, base_delay_s: float = 1.0,
          retriable=(RuntimeError, OSError), sleep=time.sleep,
          jitter: str = "none", max_delay_s: Optional[float] = None,
          rng=None):
    """Exponential backoff around transient launcher-side failures.

    The default call is bit-compatible with the historical behavior
    (pure ``base · 2^i`` delays).  ``max_delay_s`` caps the exponential
    (a deep retry otherwise sleeps for minutes), and ``jitter="full"``
    draws each delay uniformly from [0, capped delay] (AWS full jitter)
    so a fleet retrying the same outage doesn't thunder back in
    lock-step.  ``rng`` (anything with ``.uniform``; seed it for
    deterministic tests) defaults to the module-level ``random``."""
    if attempts < 1:
        raise ValueError(f"retry needs attempts >= 1, got {attempts}")
    if jitter not in ("none", "full"):
        raise ValueError(f"unknown jitter policy {jitter!r}")
    for i in range(attempts):
        try:
            return fn()
        except retriable:
            if i == attempts - 1:
                raise
            delay = base_delay_s * (2 ** i)
            if max_delay_s is not None:
                delay = min(delay, max_delay_s)
            if jitter == "full":
                delay = (rng if rng is not None else random).uniform(
                    0.0, delay)
            sleep(delay)


@dataclasses.dataclass
class ElasticController:
    """Restart policy: shrink the data axis to the surviving host count.

    The model axis is never shrunk (TP/EP shards are not replicated), so a
    failure inside a model group requires a spare from the pool first; pure
    data-parallel hosts can simply drop out.
    """
    n_hosts: int
    hosts_per_data_shard: int = 1
    min_hosts: int = 1

    def plan_after_failure(self, alive: List[int]) -> dict:
        n_alive = len(alive)
        if n_alive < self.min_hosts:
            return {"action": "abort",
                    "reason": f"only {n_alive} hosts alive"}
        # keep the largest power-of-two-ish divisible configuration
        usable = n_alive - (n_alive % self.hosts_per_data_shard)
        if usable <= 0:
            return {"action": "abort", "reason": "model group incomplete"}
        return {"action": "restart",
                "hosts": alive[:usable],
                "new_data_parallelism": usable // self.hosts_per_data_shard,
                "restore": "latest_committed"}
