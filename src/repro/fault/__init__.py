"""Fault tolerance: heartbeat, straggler detection, elastic restart driver,
and the fault-injection harness (``repro.fault.inject``)."""
from repro.fault.runtime import (ElasticController, Heartbeat, HostFailure,
                                 StragglerMonitor, retry)

__all__ = ["Heartbeat", "HostFailure", "StragglerMonitor",
           "ElasticController", "retry"]
