"""Fault tolerance: heartbeat, straggler detection, elastic restart driver."""
from repro.fault.runtime import (ElasticController, Heartbeat,
                                 StragglerMonitor, retry)

__all__ = ["Heartbeat", "StragglerMonitor", "ElasticController", "retry"]
