"""ELMO for LLM training: the chunked low-precision head on an LM vocab.

    PYTHONPATH=src python examples/lm_chunked_head.py

Trains a reduced smollm-360m for a few hundred steps with the softmax-CE
streaming-LSE head (DESIGN.md §3) — the paper's XMC technique transplanted
to a language-model vocabulary — and shows the loss decreasing, plus a
comparison of the head's memory against a naive full-logit head.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core.memory_model import GIB
from repro.launch.train import train


def main():
    cfg = get_smoke("smollm-360m", vocab=2048, head_chunks=4,
                    head_weight_dtype="e4m3")
    B, S = 8, 32
    naive_logits = B * S * cfg.vocab * 4
    chunked = B * S * (cfg.vocab // cfg.head_chunks) * 2
    print(f"full-logit buffer {naive_logits/2**20:.1f} MiB → "
          f"chunked {chunked/2**20:.1f} MiB "
          f"({naive_logits/chunked:.0f}× smaller)")
    _, losses = train(cfg, steps=200, global_batch=B, seq=S, ckpt_dir="",
                      head_lr=0.3, backbone_lr=2e-3, impl="xla",
                      log_every=25)
    # synthetic tokens are uniform: the achievable floor is ln(vocab)=7.62
    import math
    assert losses[-1] < math.log(cfg.vocab) + 0.15, losses[-1]
    assert losses[-1] < losses[0] - 0.3
    print(f"loss {losses[0]:.3f} → {losses[-1]:.3f}  lm_chunked_head OK")


if __name__ == "__main__":
    main()
