"""Batched serving: prefill + greedy decode with KV/SSM caches.

    PYTHONPATH=src python examples/serve_batched.py

Runs a reduced hybrid (attention ∥ mamba) model: prefill a batch of
prompts, then decode tokens step by step — the ``serve_step`` that the
decode_32k / long_500k dry-run cells lower at production scale.
"""
from repro.configs import get_smoke
from repro.launch.serve import serve


def main():
    for arch in ("hymba-1.5b", "smollm-360m"):
        cfg = get_smoke(arch)
        seqs, stats = serve(cfg, batch=4, prompt_len=12, gen=6, impl="xla")
        print(f"{arch}: generated shape {seqs.shape}, "
              f"prefill {stats['prefill_s']*1e3:.0f} ms, "
              f"{stats['decode_tok_s']:.1f} tok/s")
        assert seqs.shape == (4, 6)
    print("serve_batched OK")


if __name__ == "__main__":
    main()
