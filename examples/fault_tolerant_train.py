"""Fault-tolerant training: checkpoint → crash → elastic resume, plus a
corrupted-checkpoint fallback (DESIGN.md §10).

    PYTHONPATH=src python examples/fault_tolerant_train.py

Trains, checkpoints asynchronously, simulates a crash, restores from the
last committed checkpoint (including the deterministic data cursor), and
verifies the loss trajectory continues seamlessly.  Then flips one bit in
the newest committed checkpoint — restore detects the crc mismatch,
demotes it, and falls back to the previous committed step.
"""
import shutil
import tempfile

import jax

from repro.checkpoint import latest_committed, restore_checkpoint
from repro.configs import get_smoke
from repro.fault import inject
from repro.launch.steps import init_train_state
from repro.launch.train import train
from repro.optim import kahan_adamw


def main():
    cfg = get_smoke("smollm-360m", vocab=512)
    ckpt = tempfile.mkdtemp(prefix="elmo_ckpt_")
    try:
        _, losses1 = train(cfg, steps=30, global_batch=8, seq=16,
                           ckpt_dir=ckpt, impl="xla", ckpt_every=10,
                           log_every=10)
        print("-- simulated crash; restarting from last checkpoint --")
        _, losses2 = train(cfg, steps=45, global_batch=8, seq=16,
                           ckpt_dir=ckpt, impl="xla", ckpt_every=10,
                           log_every=5)
        print(f"resumed at step 30, continued to 45; "
              f"loss {losses2[0]:.3f} → {losses2[-1]:.3f}")
        assert len(losses2) == 15  # resumed from step 30, not 0

        print("-- simulated storage corruption: bit-flip the newest "
              "checkpoint --")
        newest = latest_committed(ckpt)
        assert newest.endswith("ckpt_00000040")
        inject.bit_flip_leaf(newest, leaf_index=0)
        template = init_train_state(jax.random.PRNGKey(0), cfg,
                                    kahan_adamw(), impl="xla")
        # the crc mismatch demotes ckpt 40; restore falls back to 30
        _, step, _ = restore_checkpoint(ckpt, template)
        print(f"corrupt checkpoint demoted; fell back to step {step}")
        assert step == 30, step
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)
    print("fault_tolerant_train OK")


if __name__ == "__main__":
    main()
