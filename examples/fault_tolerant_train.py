"""Fault-tolerant training: checkpoint → crash → elastic resume.

    PYTHONPATH=src python examples/fault_tolerant_train.py

Trains, checkpoints asynchronously, simulates a crash, restores from the
last committed checkpoint (including the deterministic data cursor), and
verifies the loss trajectory continues seamlessly.
"""
import shutil
import tempfile

from repro.configs import get_smoke
from repro.launch.train import train


def main():
    cfg = get_smoke("smollm-360m", vocab=512)
    ckpt = tempfile.mkdtemp(prefix="elmo_ckpt_")
    try:
        _, losses1 = train(cfg, steps=30, global_batch=8, seq=16,
                           ckpt_dir=ckpt, impl="xla", ckpt_every=10,
                           log_every=10)
        print("-- simulated crash; restarting from last checkpoint --")
        _, losses2 = train(cfg, steps=45, global_batch=8, seq=16,
                           ckpt_dir=ckpt, impl="xla", ckpt_every=10,
                           log_every=5)
        print(f"resumed at step 30, continued to 45; "
              f"loss {losses2[0]:.3f} → {losses2[-1]:.3f}")
        assert len(losses2) == 15  # resumed from step 30, not 0
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)
    print("fault_tolerant_train OK")


if __name__ == "__main__":
    main()
