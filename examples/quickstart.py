"""Quickstart: train a tiny XMC model end-to-end with the ELMO recipe.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the paper's full pipeline at laptop scale: a small bidirectional
encoder + an FP8-E4M3 chunked classifier head trained with loss-skipping,
fused stochastic-rounding SGD (no momentum, no master weights), and
Kahan-AdamW for the encoder — then reports Precision@k through the
``repro.head.ELMOHead`` facade, whose ``HeadPlan`` (execution path, block
sizes, byte budgets) is resolved once at construction and printed below.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.data import DataCursor, xmc_batches
from repro.head import ELMOHead
from repro.launch import steps as St
from repro.optim import kahan_adamw


def main():
    cfg = get_smoke("xmc-bert-3m", head_labels=5000, vocab=1000,
                    head_chunks=4)
    print(f"arch: {cfg.name}  labels={cfg.head_labels} "
          f"head={cfg.head_weight_dtype} chunks={cfg.head_chunks}")
    opt = kahan_adamw()
    state = St.init_train_state(jax.random.PRNGKey(0), cfg, opt, impl="xla")

    # one facade, one resolved plan — inspectable before any step runs
    head = ELMOHead(St.make_head_cfg(cfg, impl="xla"), batch=32,
                    target_slots=5)
    print(head.plan.explain())

    batches = xmc_batches(cfg.vocab, cfg.head_labels, global_batch=32,
                          seq=16, max_pos=5, cursor=DataCursor(0, 0))
    step = jax.jit(lambda s, t, y: St.train_step(
        cfg, opt, s, {"tokens": t, "targets": y},
        head_lr=jnp.float32(0.2), backbone_lr=jnp.float32(1e-3), impl="xla"))

    for i, b in zip(range(60), batches):
        state, m = step(state, jnp.asarray(b["tokens"]),
                        jnp.asarray(b["targets"]))
        if i % 10 == 0:
            print(f"step {i:3d}  loss {float(m['loss']):.4f}")

    # evaluate P@1 on fresh data through the facade's top-k path
    b = next(batches)
    from repro.models import transformer as T
    hidden = T.backbone_apply(state.backbone, cfg,
                              jnp.asarray(b["tokens"]))
    p1 = head.precision_at_k(state.head, hidden[:, 0, :],
                             jnp.asarray(b["targets"]), k=1)
    print(f"P@1 (synthetic): {float(p1):.3f}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
